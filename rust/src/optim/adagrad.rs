//! Adagrad (Duchi, Hazan & Singer) with heavy-ball momentum — the
//! linear-memory method SM3 is measured against (paper Eq. 1–2).

use super::backend::Backend;
use super::kernel::{self, ChunkScratch};
use super::qstate::{QuantizedSlots, StateDtype};
use super::{Optimizer, ParamSpec};
use crate::pool::Pool;
use crate::tensor::Tensor;
use anyhow::ensure;

/// Adagrad-with-momentum optimizer state over a parameter list.
pub struct Adagrad {
    beta1: f32,
    /// streaming tile (elements; multiple of the q8 block)
    chunk: usize,
    /// kernel backend for the update lanes (bitwise identical across
    /// backends — DESIGN.md §13)
    backend: Backend,
    scratch: ChunkScratch,
    /// leaf `i`: slot `2i` is the elementwise accumulator γ (Eq. 1),
    /// slot `2i + 1` is the momentum
    slots: QuantizedSlots,
    specs: Vec<ParamSpec>,
}

impl Adagrad {
    /// f32-state instance (see [`Adagrad::with_opts`]).
    pub fn new(specs: &[ParamSpec], beta1: f32) -> Self {
        Self::with_dtype(specs, beta1, StateDtype::F32)
    }

    /// Instance with explicit state-storage precision.
    pub fn with_dtype(specs: &[ParamSpec], beta1: f32,
                      dtype: StateDtype) -> Self {
        Self::with_opts(specs, beta1, dtype, kernel::DEFAULT_CHUNK)
    }

    /// Fully explicit instance: hyperparameters, storage precision, and
    /// streaming tile.
    pub fn with_opts(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
                     chunk: usize) -> Self {
        Self::build(specs, beta1, dtype, chunk, None)
    }

    /// [`Adagrad::with_opts`] with state slots and decode scratch leased
    /// from `pool` (bitwise identical to the unpooled constructor).
    pub fn with_opts_in(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
                        chunk: usize, pool: &Pool) -> Self {
        Self::build(specs, beta1, dtype, chunk, Some(pool))
    }

    fn build(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
             chunk: usize, pool: Option<&Pool>) -> Self {
        kernel::check_chunk(chunk).unwrap();
        let mut slots = match pool {
            Some(p) => QuantizedSlots::new_in(dtype, p.clone()),
            None => QuantizedSlots::new(dtype),
        };
        for s in specs {
            slots.add_zeros(s.numel()); // acc
            slots.add_zeros(s.numel()); // mom
        }
        let scratch = match pool {
            Some(p) => ChunkScratch::new_in(p),
            None => ChunkScratch::default(),
        };
        Self { beta1, chunk, backend: Backend::default(),
               scratch, slots,
               specs: specs.to_vec() }
    }

    /// Route the update lanes and the state store's codec lanes through
    /// `backend` (bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
        self.slots.set_backend(backend);
    }

    /// The full elementwise second-moment statistics γ_t (Fig. 1 / Fig. 5),
    /// dequantized to f32.
    pub fn accumulator(&self, idx: usize) -> Tensor {
        Tensor::from_vec(&self.specs[idx].shape, self.slots.to_vec(2 * idx))
    }
}

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let beta1 = self.beta1;
        let be = self.backend.imp();
        for idx in 0..params.len() {
            kernel::step_chunked2(
                &mut self.slots, 2 * idx, 2 * idx + 1, self.chunk,
                &mut self.scratch, params[idx].data_mut(), grads[idx].data(),
                |w, g, acc, mom| {
                    be.adagrad_update(beta1, lr, w, g, acc, mom)
                });
        }
    }

    fn step_flat(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(self.specs.len(), 1,
                   "step_flat needs a single-leaf instance");
        let beta1 = self.beta1;
        let be = self.backend.imp();
        kernel::step_chunked2(&mut self.slots, 0, 1, self.chunk,
                              &mut self.scratch, w, g, |w, g, acc, mom| {
            be.adagrad_update(beta1, lr, w, g, acc, mom)
        });
    }

    fn state_floats(&self) -> usize {
        self.slots.state_floats()
    }

    fn state_bytes(&self) -> usize {
        self.slots.state_bytes()
    }

    fn state_dtype(&self) -> StateDtype {
        self.slots.dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for (i, s) in self.specs.iter().enumerate() {
            out.push((i, "acc",
                      Tensor::from_vec(&s.shape, self.slots.to_vec(2 * i))));
            out.push((i, "mom",
                      Tensor::from_vec(&s.shape,
                                       self.slots.to_vec(2 * i + 1))));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        let want = 2 * self.specs.len();
        ensure!(state.len() == want,
                "adagrad state layout mismatch: got {} tensors, expected \
                 {} (acc/mom per leaf over {} leaves)",
                state.len(), want, self.specs.len());
        let mut it = state.into_iter();
        for (i, s) in self.specs.iter().enumerate() {
            for (slot, kind) in [(2 * i, "acc"), (2 * i + 1, "mom")] {
                let t = it.next().expect("length checked above");
                ensure!(t.shape() == s.shape.as_slice(),
                        "adagrad leaf {:?} slot {kind}: state shape {:?}, \
                         expected {:?}", s.name, t.shape(), s.shape);
                self.slots.write(slot, t.data());
            }
        }
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        self.scratch.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn accumulator_is_sum_of_squares() {
        let specs = vec![ParamSpec::new("w", &[4])];
        let mut opt = Adagrad::new(&specs, 0.0);
        let mut params = vec![Tensor::zeros(&[4])];
        let mut expect = vec![0.0f32; 4];
        let mut rng = Rng::new(0);
        for _ in 0..8 {
            let g = Tensor::randn(&[4], 1.0, &mut rng);
            for (e, &gv) in expect.iter_mut().zip(g.data()) {
                *e += gv * gv;
            }
            opt.step(&mut params, &[g], 0.1);
        }
        for (a, e) in opt.accumulator(0).data().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-4);
        }
    }

    #[test]
    fn effective_lr_decays() {
        // repeated identical gradients: |Δw| shrinks like 1/sqrt(t)
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = Adagrad::new(&specs, 0.0);
        let mut params = vec![Tensor::zeros(&[1])];
        let g = Tensor::from_vec(&[1], vec![2.0]);
        let mut prev = f32::INFINITY;
        for _ in 0..10 {
            let before = params[0].data()[0];
            opt.step(&mut params, std::slice::from_ref(&g), 0.1);
            let delta = (params[0].data()[0] - before).abs();
            assert!(delta < prev);
            prev = delta;
        }
    }

    /// The f32 store must be bit-transparent: quantize-on-write with
    /// `StateDtype::F32` is a plain copy, so the accumulator trajectory
    /// matches exact f64-side bookkeeping as tightly as the seed code did.
    #[test]
    fn f32_store_roundtrip_is_exact() {
        let specs = vec![ParamSpec::new("w", &[3, 5])];
        let mut opt = Adagrad::new(&specs, 0.9);
        let mut rng = Rng::new(7);
        let mut params = vec![Tensor::randn(&[3, 5], 1.0, &mut rng)];
        let g = Tensor::randn(&[3, 5], 1.0, &mut rng);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        let acc = opt.accumulator(0);
        for (a, gv) in acc.data().iter().zip(g.data()) {
            assert_eq!(a.to_bits(), (gv * gv).to_bits());
        }
    }

    #[test]
    fn q8_state_roundtrips_through_state_api() {
        let specs =
            vec![ParamSpec::new("w", &[9, 8]), ParamSpec::new("b", &[70])];
        let mut opt = Adagrad::with_dtype(&specs, 0.9, StateDtype::Q8);
        let mut rng = Rng::new(3);
        let mut params: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        for _ in 0..4 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            opt.step(&mut params, &grads, 0.1);
        }
        let saved: Vec<Tensor> =
            opt.state().into_iter().map(|(_, _, t)| t).collect();
        let mut fresh = Adagrad::with_dtype(&specs, 0.9, StateDtype::Q8);
        fresh.load_state(saved.clone()).unwrap();
        let restored: Vec<Tensor> =
            fresh.state().into_iter().map(|(_, _, t)| t).collect();
        // dequantized values re-quantize to the identical codes, so the
        // round-trip is bitwise (the codec idempotence contract)
        assert_eq!(saved, restored);
    }
}
