//! Adagrad (Duchi, Hazan & Singer) with heavy-ball momentum — the
//! linear-memory method SM3 is measured against (paper Eq. 1–2).

use super::{safe_rsqrt, Optimizer, ParamSpec};
use crate::tensor::Tensor;

pub struct Adagrad {
    beta1: f32,
    /// per-parameter elementwise accumulator γ (Eq. 1)
    acc: Vec<Tensor>,
    mom: Vec<Tensor>,
}

impl Adagrad {
    pub fn new(specs: &[ParamSpec], beta1: f32) -> Self {
        Self {
            beta1,
            acc: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
            mom: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
        }
    }

    /// The full elementwise second-moment statistics γ_t (Fig. 1 / Fig. 5).
    pub fn accumulator(&self, idx: usize) -> &Tensor {
        &self.acc[idx]
    }
}

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let beta1 = self.beta1;
        for idx in 0..params.len() {
            let wd = params[idx].data_mut();
            let gd = grads[idx].data();
            let acc = self.acc[idx].data_mut();
            let mom = self.mom[idx].data_mut();
            for k in 0..wd.len() {
                let nu = acc[k] + gd[k] * gd[k];
                let upd = gd[k] * safe_rsqrt(nu);
                mom[k] = beta1 * mom[k] + (1.0 - beta1) * upd;
                wd[k] -= lr * mom[k];
                acc[k] = nu;
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.acc.iter().map(Tensor::len).sum::<usize>()
            + self.mom.iter().map(Tensor::len).sum::<usize>()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for i in 0..self.acc.len() {
            out.push((i, "acc", self.acc[i].clone()));
            out.push((i, "mom", self.mom[i].clone()));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        let mut it = state.into_iter();
        for i in 0..self.acc.len() {
            self.acc[i] = it.next().expect("state underrun");
            self.mom[i] = it.next().expect("state underrun");
        }
        assert!(it.next().is_none());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn accumulator_is_sum_of_squares() {
        let specs = vec![ParamSpec::new("w", &[4])];
        let mut opt = Adagrad::new(&specs, 0.0);
        let mut params = vec![Tensor::zeros(&[4])];
        let mut expect = vec![0.0f32; 4];
        let mut rng = Rng::new(0);
        for _ in 0..8 {
            let g = Tensor::randn(&[4], 1.0, &mut rng);
            for (e, &gv) in expect.iter_mut().zip(g.data()) {
                *e += gv * gv;
            }
            opt.step(&mut params, &[g], 0.1);
        }
        for (a, e) in opt.accumulator(0).data().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-4);
        }
    }

    #[test]
    fn effective_lr_decays() {
        // repeated identical gradients: |Δw| shrinks like 1/sqrt(t)
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = Adagrad::new(&specs, 0.0);
        let mut params = vec![Tensor::zeros(&[1])];
        let g = Tensor::from_vec(&[1], vec![2.0]);
        let mut prev = f32::INFINITY;
        for _ in 0..10 {
            let before = params[0].data()[0];
            opt.step(&mut params, std::slice::from_ref(&g), 0.1);
            let delta = (params[0].data()[0] - before).abs();
            assert!(delta < prev);
            prev = delta;
        }
    }
}
