//! The Layer-3 optimizer bank.
//!
//! Pure-Rust implementations of SM3-I, SM3-II, Adagrad, Adam, Adafactor and
//! SGD+momentum, bit-compatible (same f32 op order) with the Layer-1 Pallas
//! kernels and their jnp oracles. These drive the *split* execution path
//! (grad artifact → host-side update), power optimizer-state introspection
//! for the Fig. 1/5/7 traces, checkpointing, and the memory accountant.
//!
//! The fused path (optimizer inside the HLO artifact) bypasses this module
//! entirely; cross-path equality is asserted in `rust/tests/`.
//!
//! Construction goes through the typed, composable [`OptimSpec`] builder
//! ([`api`], DESIGN.md §11): per-method hyperparameters, state-storage
//! options, chainable update transforms ([`transform`]: clipping,
//! decoupled weight decay), and per-parameter-group overrides. The
//! free-function constructors ([`build`] and friends) remain as thin
//! deprecated shims for one release.

#![warn(missing_docs)]

mod adafactor;
mod adagrad;
mod adam;
pub mod api;
pub mod backend;
pub mod cover;
pub mod kernel;
pub mod parallel;
pub mod qstate;
pub mod schedule;
mod sgdm;
mod sm3;
pub mod transform;

pub use adafactor::Adafactor;
pub use adagrad::Adagrad;
pub use adam::Adam;
pub use api::{AdafactorHp, AdagradHp, AdamHp, GroupSpec, Method, OptimSpec,
              SgdmHp, Sm3Hp, StateOpts};
pub use backend::{Backend, KernelBackend, ScalarBackend, SimdBackend};
pub use parallel::{ParallelStep, SplitPolicy};
pub use qstate::{QuantizedSlots, StateDtype};
pub use sgdm::SgdMomentum;
pub use sm3::{Sm3, Sm3Variant};
pub use transform::{clip_by_global_norm, clip_by_value,
                    decoupled_weight_decay, identity, Pipeline,
                    UpdateTransform};

use crate::tensor::Tensor;

/// `1/sqrt(nu)` with the paper's `0/0 = 0` convention (no epsilon), f32.
///
/// A NaN accumulator fails `nu > 0.0` and would silently map the update to
/// 0.0 — masking NaN *gradients* instead of surfacing them; debug builds
/// assert so the first poisoned step panics at its source.
#[inline(always)]
pub(crate) fn safe_rsqrt(nu: f32) -> f32 {
    debug_assert!(!nu.is_nan(),
                  "NaN second-moment accumulator (NaN gradient?)");
    if nu > 0.0 {
        1.0 / nu.sqrt()
    } else {
        0.0
    }
}

/// Shape-and-name description of one parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    /// Leaf name ("embed", "l0/wq", …) — what param-group patterns match.
    pub name: String,
    /// Tensor shape; rank decides the SM3 cover and split eligibility.
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Build a spec from a name and shape.
    pub fn new(name: impl Into<String>, shape: &[usize]) -> Self {
        Self { name: name.into(), shape: shape.to_vec() }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A first-order optimizer over a fixed list of parameter tensors.
///
/// `step` applies one update in place; `lr` is the *post-schedule* learning
/// rate for this step (warmup/decay live in [`schedule`]).
pub trait Optimizer: Send {
    /// Short name ("sm3", "adam", ...) matching the artifact registry.
    fn name(&self) -> &'static str;

    /// Apply one update step in place.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32);

    /// Total optimizer-state scalars (the paper's memory quantity).
    fn state_floats(&self) -> usize;

    /// Exact storage bytes of the state (q8 includes per-block scales).
    /// Defaults to 4 bytes/scalar — the f32 storage every optimizer used
    /// before the qstate subsystem.
    fn state_bytes(&self) -> usize {
        self.state_floats() * 4
    }

    /// Storage precision of the state slots (DESIGN.md §10).
    fn state_dtype(&self) -> qstate::StateDtype {
        qstate::StateDtype::F32
    }

    /// Apply one update step to a **single-leaf** instance through flat
    /// f32 views of its parameter and gradient data — the entry point
    /// `ParallelStep`'s intra-leaf sharding drives, where one dominant
    /// leaf is split into q8-block-aligned ranges each owned by a
    /// sub-optimizer over a flat sub-spec. Only meaningful where
    /// [`kernel::elementwise`] holds for the leaf; the default panics.
    fn step_flat(&mut self, _w: &mut [f32], _g: &[f32], _lr: f32) {
        panic!("step_flat: {} is not an element-wise optimizer", self.name());
    }

    /// Named state tensors for checkpointing / introspection, in a stable
    /// order: `(param_index, slot_name, tensor)`. Tensors are cloned — this
    /// is a checkpoint/trace path, not the hot loop.
    fn state(&self) -> Vec<(usize, &'static str, Tensor)>;

    /// Restore state saved by [`Optimizer::state`] (same order). A
    /// layout mismatch (wrong tensor count, wrong leaf shape) is an
    /// `Err` naming the leaf and the expected layout — restore paths
    /// must not panic on malformed checkpoints.
    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()>;

    /// Live bytes currently held by this optimizer's *working* scratch
    /// (decode tiles, leaf-granular two-pass buffers) — the quantity
    /// the pool attributes to [`crate::pool::Tag::KernelScratch`] for a
    /// pooled instance. Scratch is sized lazily by the first steps, so
    /// this is a live query, not a static formula. Default 0 (no
    /// scratch).
    fn scratch_bytes(&self) -> usize {
        0
    }
}

/// Construct an optimizer by registry name with f32 state storage.
///
/// Deprecated shim over [`OptimSpec`]: `beta2` applies only where the
/// method has one (Adam, Adafactor), Adam's `eps` stays at the historic
/// `1e-8`. Use the builder for anything beyond that.
#[deprecated(note = "use optim::OptimSpec (DESIGN.md §11); this shim \
                     remains for one release")]
pub fn build(name: &str, specs: &[ParamSpec], beta1: f32, beta2: f32)
             -> anyhow::Result<Box<dyn Optimizer>> {
    shim_build(name, specs, beta1, beta2, StateDtype::F32,
               kernel::DEFAULT_CHUNK)
}

/// Construct an optimizer by registry name with the given state-storage
/// precision (config key `state_dtype`, DESIGN.md §10) and the default
/// streaming tile. Deprecated shim over [`OptimSpec`].
#[deprecated(note = "use optim::OptimSpec (DESIGN.md §11); this shim \
                     remains for one release")]
pub fn build_with_dtype(name: &str, specs: &[ParamSpec], beta1: f32,
                        beta2: f32, dtype: StateDtype)
                        -> anyhow::Result<Box<dyn Optimizer>> {
    shim_build(name, specs, beta1, beta2, dtype, kernel::DEFAULT_CHUNK)
}

/// Construct an optimizer by registry name with explicit state-storage
/// precision and streaming tile size (config key `step_chunk`; must be a
/// positive multiple of the q8 block). Deprecated shim over
/// [`OptimSpec`] — the end of the telescoping-constructor line this
/// builder replaces.
#[deprecated(note = "use optim::OptimSpec (DESIGN.md §11); this shim \
                     remains for one release")]
pub fn build_with_opts(name: &str, specs: &[ParamSpec], beta1: f32,
                       beta2: f32, dtype: StateDtype, chunk: usize)
                       -> anyhow::Result<Box<dyn Optimizer>> {
    shim_build(name, specs, beta1, beta2, dtype, chunk)
}

/// The one implementation behind the deprecated shims: exactly
/// `OptimSpec` with the legacy positional arguments applied.
fn shim_build(name: &str, specs: &[ParamSpec], beta1: f32, beta2: f32,
              dtype: StateDtype, chunk: usize)
              -> anyhow::Result<Box<dyn Optimizer>> {
    OptimSpec::named(name)?
        .beta1(beta1)
        .beta2(beta2)
        .state_dtype(dtype)
        .step_chunk(chunk)
        .build(specs)
}

/// All registry names, in the order the paper's tables list them.
pub const ALL: &[&str] = &["adam", "adagrad", "adafactor", "sm3", "sm3i", "sgdm"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn quad_specs() -> Vec<ParamSpec> {
        vec![ParamSpec::new("w", &[8, 6]), ParamSpec::new("b", &[6])]
    }

    /// Minimizing a convex quadratic: every optimizer must reduce the loss.
    #[test]
    fn all_optimizers_descend_on_quadratic() {
        for name in ALL {
            let specs = quad_specs();
            let mut opt =
                OptimSpec::named(name).unwrap().build(&specs).unwrap();
            let mut rng = Rng::new(0);
            let target_w = Tensor::randn(&[8, 6], 1.0, &mut rng);
            let target_b = Tensor::randn(&[6], 1.0, &mut rng);
            let mut params = vec![Tensor::zeros(&[8, 6]), Tensor::zeros(&[6])];
            let loss = |p: &[Tensor]| -> f64 {
                p[0].zip(&target_w, |a, b| (a - b) * (a - b)).sq_norm().sqrt()
                    + p[1].zip(&target_b, |a, b| (a - b) * (a - b)).sq_norm().sqrt()
            };
            let l0 = loss(&params);
            let lr = match *name {
                "sgdm" => 0.02,
                "adam" => 0.05,
                _ => 0.3,
            };
            for _ in 0..200 {
                let gw = params[0].zip(&target_w, |a, b| 2.0 * (a - b));
                let gb = params[1].zip(&target_b, |a, b| 2.0 * (a - b));
                let grads = vec![gw, gb];
                let (a, b) = params.split_at_mut(1);
                let mut all = Vec::new();
                all.extend(a.iter().cloned());
                all.extend(b.iter().cloned());
                opt.step(&mut all, &grads, lr);
                params = all;
            }
            let l1 = loss(&params);
            assert!(l1 < 0.5 * l0, "{name}: {l0} -> {l1}");
        }
    }

    /// Storage precision must not break optimization: every registry
    /// optimizer still descends on the convex quadratic with bf16 and q8
    /// state (the update arithmetic is f32 either way; only the state
    /// carried between steps is rounded).
    #[test]
    fn all_optimizers_descend_with_quantized_state() {
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            for name in ALL {
                let specs = quad_specs();
                let mut opt = OptimSpec::named(name).unwrap()
                    .state_dtype(dtype).build(&specs).unwrap();
                assert_eq!(opt.state_dtype(), dtype);
                let mut rng = Rng::new(0);
                let target_w = Tensor::randn(&[8, 6], 1.0, &mut rng);
                let target_b = Tensor::randn(&[6], 1.0, &mut rng);
                let mut params =
                    vec![Tensor::zeros(&[8, 6]), Tensor::zeros(&[6])];
                let loss = |p: &[Tensor]| -> f64 {
                    p[0].zip(&target_w, |a, b| (a - b) * (a - b))
                        .sq_norm().sqrt()
                        + p[1].zip(&target_b, |a, b| (a - b) * (a - b))
                            .sq_norm().sqrt()
                };
                let l0 = loss(&params);
                let lr = match *name {
                    "sgdm" => 0.02,
                    "adam" => 0.05,
                    _ => 0.3,
                };
                for _ in 0..200 {
                    let gw = params[0].zip(&target_w, |a, b| 2.0 * (a - b));
                    let gb = params[1].zip(&target_b, |a, b| 2.0 * (a - b));
                    let grads = vec![gw, gb];
                    opt.step(&mut params, &grads, lr);
                }
                let l1 = loss(&params);
                assert!(l1 < 0.6 * l0, "{name} @ {dtype:?}: {l0} -> {l1}");
            }
        }
    }

    /// The quantized stores really are smaller, on a live optimizer.
    #[test]
    fn state_bytes_shrink_with_dtype() {
        let specs = quad_specs();
        for name in ALL {
            let by = |d: StateDtype| OptimSpec::named(name).unwrap()
                .state_dtype(d).build(&specs).unwrap().state_bytes();
            let (f32b, bf16b, q8b) = (by(StateDtype::F32),
                                      by(StateDtype::Bf16),
                                      by(StateDtype::Q8));
            assert_eq!(bf16b * 2, f32b, "{name}");
            assert!(q8b < bf16b, "{name}: q8 {q8b} vs bf16 {bf16b}");
        }
    }

    #[test]
    fn state_floats_ordering_matches_paper() {
        // Adam = 2d, Adagrad(+m) = 2d, SGD+m = d,
        // SM3(+m) = d + sum(slices), Adafactor(+m) = d + rows+cols.
        let specs = vec![ParamSpec::new("emb", &[1000, 64]),
                         ParamSpec::new("b", &[64])];
        let d: usize = specs.iter().map(|s| s.numel()).sum();
        let f = |n: &str| OptimSpec::named(n).unwrap()
            .build(&specs).unwrap().state_floats();
        assert_eq!(f("adam"), 2 * d);
        assert_eq!(f("adagrad"), 2 * d);
        assert_eq!(f("sgdm"), d);
        assert_eq!(f("sm3"), d + (1000 + 64) + 64);
        assert_eq!(f("adafactor"), d + (1000 + 64) + 64);
        assert!(f("sm3") < f("adam"));
    }

    /// The deprecated shims stay behaviorally intact for one release:
    /// same errors, same defaults.
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_validate() {
        assert!(build("nope", &quad_specs(), 0.9, 0.98).is_err());
        assert!(build_with_opts("adam", &quad_specs(), 0.9, 0.98,
                                StateDtype::F32, 0).is_err());
        assert!(build_with_opts("adam", &quad_specs(), 0.9, 0.98,
                                StateDtype::F32, 100).is_err());
        assert!(build_with_opts("adam", &quad_specs(), 0.9, 0.98,
                                StateDtype::F32, 64).is_ok());
    }

    #[test]
    fn unknown_name_errors() {
        assert!(OptimSpec::named("nope").is_err());
    }

    #[test]
    fn bad_chunk_errors() {
        let specs = quad_specs();
        assert!(OptimSpec::named("adam").unwrap().step_chunk(0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().step_chunk(100)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().step_chunk(64)
            .build(&specs).is_ok());
    }

    /// ISSUE 3 satellite: after a few warmup steps every optimizer's
    /// `step()` is allocation-free at every state dtype — the chunked
    /// kernels stream through reused scratch, and the leaf-granular
    /// paths (SM3 matrix/tensor, Adafactor) keep their buffers in the
    /// struct. Verified with the thread-local counting allocator
    /// (`crate::alloc_count`), so concurrent test threads cannot perturb
    /// the count.
    #[test]
    fn steady_state_steps_are_allocation_free() {
        // matrix, odd-length vector, and rank-4 tensor leaves together
        // exercise the chunked, factored, and generic-cover paths
        let specs = vec![ParamSpec::new("emb", &[40, 8]),
                         ParamSpec::new("conv", &[3, 3, 2, 4]),
                         ParamSpec::new("b", &[70])];
        let mut rng = Rng::new(1);
        let params0: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        for dtype in StateDtype::ALL {
            for name in ALL {
                let mut opt = OptimSpec::named(name).unwrap()
                    .state_dtype(dtype).step_chunk(64)
                    .build(&specs).unwrap();
                let mut params = params0.clone();
                for _ in 0..3 {
                    opt.step(&mut params, &grads, 0.1); // warm capacities
                }
                let before = crate::alloc_count::thread_allocs();
                for _ in 0..2 {
                    opt.step(&mut params, &grads, 0.1);
                }
                let allocs = crate::alloc_count::thread_allocs() - before;
                assert_eq!(allocs, 0,
                           "{name} @ {dtype:?}: {allocs} allocations in \
                            steady-state steps");
            }
        }
    }

    /// Regression (debug builds): a NaN gradient must panic at the first
    /// poisoned accumulator instead of being masked into a 0.0 update.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN second-moment accumulator")]
    fn nan_gradients_are_surfaced_not_masked() {
        let specs = vec![ParamSpec::new("w", &[4])];
        let mut opt =
            OptimSpec::named("sm3").unwrap().build(&specs).unwrap();
        let mut params = vec![Tensor::zeros(&[4])];
        let g = vec![Tensor::full(&[4], f32::NAN)];
        opt.step(&mut params, &g, 0.1);
    }

    /// Release builds keep the branchless 0/0 = 0 path; NaN maps to 0.0
    /// there (documented), and non-NaN inputs behave identically in both.
    #[test]
    fn safe_rsqrt_convention() {
        assert_eq!(safe_rsqrt(0.0), 0.0);
        assert_eq!(safe_rsqrt(-1.0), 0.0);
        assert_eq!(safe_rsqrt(4.0), 0.5);
    }
}
