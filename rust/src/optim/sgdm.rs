//! SGD with heavy-ball momentum — the non-adaptive baseline
//! (paper §5.3, AmoebaNet).

use super::backend::Backend;
use super::kernel::{self, ChunkScratch};
use super::qstate::{QuantizedSlots, StateDtype};
use super::{Optimizer, ParamSpec};
use crate::pool::Pool;
use crate::tensor::Tensor;
use anyhow::ensure;

/// SGD-with-momentum optimizer state over a parameter list.
pub struct SgdMomentum {
    beta1: f32,
    /// streaming tile (elements; multiple of the q8 block)
    chunk: usize,
    /// kernel backend for the update lanes (bitwise identical across
    /// backends — DESIGN.md §13)
    backend: Backend,
    scratch: ChunkScratch,
    /// slot `i` holds leaf `i`'s momentum
    slots: QuantizedSlots,
    specs: Vec<ParamSpec>,
}

impl SgdMomentum {
    /// f32-state instance (see [`SgdMomentum::with_opts`]).
    pub fn new(specs: &[ParamSpec], beta1: f32) -> Self {
        Self::with_dtype(specs, beta1, StateDtype::F32)
    }

    /// Instance with explicit state-storage precision.
    pub fn with_dtype(specs: &[ParamSpec], beta1: f32,
                      dtype: StateDtype) -> Self {
        Self::with_opts(specs, beta1, dtype, kernel::DEFAULT_CHUNK)
    }

    /// Fully explicit instance: hyperparameters, storage precision, and
    /// streaming tile.
    pub fn with_opts(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
                     chunk: usize) -> Self {
        Self::build(specs, beta1, dtype, chunk, None)
    }

    /// [`SgdMomentum::with_opts`] with state slots and decode scratch
    /// leased from `pool` (bitwise identical to the unpooled
    /// constructor).
    pub fn with_opts_in(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
                        chunk: usize, pool: &Pool) -> Self {
        Self::build(specs, beta1, dtype, chunk, Some(pool))
    }

    fn build(specs: &[ParamSpec], beta1: f32, dtype: StateDtype,
             chunk: usize, pool: Option<&Pool>) -> Self {
        kernel::check_chunk(chunk).unwrap();
        let mut slots = match pool {
            Some(p) => QuantizedSlots::new_in(dtype, p.clone()),
            None => QuantizedSlots::new(dtype),
        };
        for s in specs {
            slots.add_zeros(s.numel());
        }
        let scratch = match pool {
            Some(p) => ChunkScratch::new_in(p),
            None => ChunkScratch::default(),
        };
        Self { beta1, chunk, backend: Backend::default(),
               scratch, slots,
               specs: specs.to_vec() }
    }

    /// Route the update lanes and the state store's codec lanes through
    /// `backend` (bitwise identical across backends).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
        self.slots.set_backend(backend);
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgdm"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let b1 = self.beta1;
        let be = self.backend.imp();
        for idx in 0..params.len() {
            kernel::step_chunked1(
                &mut self.slots, idx, self.chunk, &mut self.scratch,
                params[idx].data_mut(), grads[idx].data(),
                |w, g, mom| be.sgdm_update(b1, lr, w, g, mom));
        }
    }

    fn step_flat(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(self.specs.len(), 1,
                   "step_flat needs a single-leaf instance");
        let b1 = self.beta1;
        let be = self.backend.imp();
        kernel::step_chunked1(&mut self.slots, 0, self.chunk,
                              &mut self.scratch, w, g,
                              |w, g, mom| be.sgdm_update(b1, lr, w, g, mom));
    }

    fn state_floats(&self) -> usize {
        self.slots.state_floats()
    }

    fn state_bytes(&self) -> usize {
        self.slots.state_bytes()
    }

    fn state_dtype(&self) -> StateDtype {
        self.slots.dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        self.specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                (i, "mom", Tensor::from_vec(&s.shape, self.slots.to_vec(i)))
            })
            .collect()
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        ensure!(state.len() == self.specs.len(),
                "sgdm state layout mismatch: got {} tensors, expected {} \
                 (one momentum per leaf)", state.len(), self.specs.len());
        for (i, t) in state.into_iter().enumerate() {
            let s = &self.specs[i];
            ensure!(t.shape() == s.shape.as_slice(),
                    "sgdm leaf {:?} slot mom: state shape {:?}, expected \
                     {:?}", s.name, t.shape(), s.shape);
            self.slots.write(i, t.data());
        }
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        self.scratch.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_accumulates() {
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = SgdMomentum::new(&specs, 0.9);
        let mut params = vec![Tensor::zeros(&[1])];
        let g = Tensor::from_vec(&[1], vec![1.0]);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        let d1 = -params[0].data()[0];
        let w1 = params[0].data()[0];
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        let d2 = w1 - params[0].data()[0];
        assert!((d1 - 0.1).abs() < 1e-6);
        assert!((d2 - 0.19).abs() < 1e-6); // lr*(0.9*1 + 1)
    }

    #[test]
    fn quantized_state_shrinks_and_still_descends() {
        let specs = vec![ParamSpec::new("w", &[64, 4])];
        let f32_bytes = SgdMomentum::new(&specs, 0.9).state_bytes();
        let mut opt =
            SgdMomentum::with_dtype(&specs, 0.9, StateDtype::Q8);
        assert!(opt.state_bytes() * 3 < f32_bytes,
                "q8 {} vs f32 {f32_bytes}", opt.state_bytes());
        assert_eq!(opt.state_dtype(), StateDtype::Q8);
        let mut params = vec![Tensor::full(&[64, 4], 1.0)];
        let g = vec![Tensor::full(&[64, 4], 0.5)];
        for _ in 0..10 {
            opt.step(&mut params, &g, 0.1);
        }
        // constant positive gradient: every weight must have moved down
        assert!(params[0].data().iter().all(|&v| v < 1.0));
    }
}
