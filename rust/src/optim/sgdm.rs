//! SGD with heavy-ball momentum — the non-adaptive baseline
//! (paper §5.3, AmoebaNet).

use super::{Optimizer, ParamSpec};
use crate::tensor::Tensor;

pub struct SgdMomentum {
    beta1: f32,
    mom: Vec<Tensor>,
}

impl SgdMomentum {
    pub fn new(specs: &[ParamSpec], beta1: f32) -> Self {
        Self {
            beta1,
            mom: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
        }
    }
}

impl Optimizer for SgdMomentum {
    fn name(&self) -> &'static str {
        "sgdm"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let b1 = self.beta1;
        for idx in 0..params.len() {
            let wd = params[idx].data_mut();
            let gd = grads[idx].data();
            let mom = self.mom[idx].data_mut();
            for k in 0..wd.len() {
                mom[k] = b1 * mom[k] + gd[k];
                wd[k] -= lr * mom[k];
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.mom.iter().map(Tensor::len).sum()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        self.mom.iter().cloned().enumerate()
            .map(|(i, t)| (i, "mom", t)).collect()
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        assert_eq!(state.len(), self.mom.len());
        self.mom = state;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_accumulates() {
        let specs = vec![ParamSpec::new("w", &[1])];
        let mut opt = SgdMomentum::new(&specs, 0.9);
        let mut params = vec![Tensor::zeros(&[1])];
        let g = Tensor::from_vec(&[1], vec![1.0]);
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        let d1 = -params[0].data()[0];
        let w1 = params[0].data()[0];
        opt.step(&mut params, std::slice::from_ref(&g), 0.1);
        let d2 = w1 - params[0].data()[0];
        assert!((d1 - 0.1).abs() < 1e-6);
        assert!((d2 - 0.19).abs() < 1e-6); // lr*(0.9*1 + 1)
    }
}
