//! Adafactor (Shazeer & Stern) — the sublinear-memory comparator
//! (paper §4 "Comparison with Adafactor").
//!
//! Matrix parameters keep factored row/col second-moment estimates
//! (Θ(m+n) like SM3); vectors fall back to the full second moment.
//! Rank>2 tensors are folded to (Π leading dims, last dim) matrices —
//! Adafactor is matrix-only by construction. Update clipping at RMS 1.0
//! (the reference implementation's d=1.0) and β1 momentum, matching the
//! paper's experimental setup (all methods run with momentum).

use super::backend::Backend;
use super::qstate::{QuantizedSlots, StateDtype};
use super::{Optimizer, ParamSpec};
use crate::pool::{Pool, PoolBuf, Tag};
use crate::tensor::Tensor;
use anyhow::ensure;

const EPS: f32 = 1e-30;

/// Second-moment layout of one leaf; fields are slot ids in the store.
#[derive(Clone, Copy)]
enum SlotKind {
    Factored { vr: usize, vc: usize, rows: usize, cols: usize },
    Full { v: usize },
}

impl SlotKind {
    /// Human-readable kind for mismatch diagnostics.
    fn describe(&self) -> String {
        match self {
            SlotKind::Factored { rows, cols, .. } => {
                format!("factored (vr[{rows}], vc[{cols}])")
            }
            SlotKind::Full { .. } => "full elementwise v".to_string(),
        }
    }
}

/// Adafactor optimizer state over a parameter list (factored row/col
/// second moments for matrices, full vector otherwise).
pub struct Adafactor {
    beta1: f32,
    beta2: f32,
    kinds: Vec<SlotKind>,
    /// momentum slot id per leaf
    mom_ids: Vec<usize>,
    store: QuantizedSlots,
    specs: Vec<ParamSpec>,
    /// Scratch for the unclipped update, plus dequantize buffers for the
    /// momentum and the row/col (or full-v) statistics — all struct-held
    /// and reused across leaves and steps, so steady-state `step()` calls
    /// are allocation-free (asserted by the counting-allocator test in
    /// `optim::tests`; ISSUE 3 satellite). Resident cost: Θ(largest
    /// leaf) for a whole-model instance — free, since the RMS clip makes
    /// that buffer live during every step anyway. Under `ParallelStep`
    /// (one Adafactor per leaf — never split: the clip is a whole-leaf
    /// reduction) the retained buffers sum to ~2·d floats across
    /// instances, trading resident bytes for allocation-free steps; PR 2
    /// made the opposite call, this PR's satellite reverses it. Pooled
    /// instances lease these under [`Tag::KernelScratch`].
    scratch: PoolBuf<f32>,
    mom_buf: PoolBuf<f32>,
    stat_a: PoolBuf<f32>,
    stat_b: PoolBuf<f32>,
}

impl Adafactor {
    /// f32-state instance (see [`Adafactor::with_dtype`]).
    pub fn new(specs: &[ParamSpec], beta1: f32, beta2: f32) -> Self {
        Self::with_dtype(specs, beta1, beta2, StateDtype::F32)
    }

    /// Instance with explicit state-storage precision (Adafactor is
    /// leaf-granular — no streaming tile).
    pub fn with_dtype(specs: &[ParamSpec], beta1: f32, beta2: f32,
                      dtype: StateDtype) -> Self {
        Self::build(specs, beta1, beta2, dtype, None)
    }

    /// [`Adafactor::with_dtype`] with state slots and all working
    /// scratch leased from `pool` (bitwise identical to the unpooled
    /// constructor).
    pub fn with_dtype_in(specs: &[ParamSpec], beta1: f32, beta2: f32,
                         dtype: StateDtype, pool: &Pool) -> Self {
        Self::build(specs, beta1, beta2, dtype, Some(pool))
    }

    fn build(specs: &[ParamSpec], beta1: f32, beta2: f32,
             dtype: StateDtype, pool: Option<&Pool>) -> Self {
        let mut store = match pool {
            Some(p) => QuantizedSlots::new_in(dtype, p.clone()),
            None => QuantizedSlots::new(dtype),
        };
        let mut kinds = Vec::with_capacity(specs.len());
        let mut mom_ids = Vec::with_capacity(specs.len());
        for s in specs {
            if s.shape.len() >= 2 {
                let cols = *s.shape.last().unwrap();
                let rows = s.numel() / cols;
                let vr = store.add_zeros(rows);
                let vc = store.add_zeros(cols);
                kinds.push(SlotKind::Factored { vr, vc, rows, cols });
            } else {
                let v = store.add_zeros(s.numel());
                kinds.push(SlotKind::Full { v });
            }
            mom_ids.push(store.add_zeros(s.numel()));
        }
        let lease = || match pool {
            Some(p) => p.take_f32(Tag::KernelScratch, 0),
            None => PoolBuf::unpooled(Tag::KernelScratch),
        };
        Self { beta1, beta2, kinds, mom_ids, store,
               specs: specs.to_vec(), scratch: lease(),
               mom_buf: lease(), stat_a: lease(), stat_b: lease() }
    }

    /// Route the state store's codec lanes through `backend` (bitwise
    /// identical across backends — DESIGN.md §13). Adafactor's update
    /// loops are reduction-coupled (row/col means, whole-leaf RMS clip)
    /// and stay leaf-granular indexed code.
    pub fn set_backend(&mut self, backend: Backend) {
        self.store.set_backend(backend);
    }

    /// (rows, cols) of a factored leaf, `None` for a full-v leaf (tests).
    pub fn factored_dims(&self, idx: usize) -> Option<(usize, usize)> {
        match self.kinds[idx] {
            SlotKind::Factored { rows, cols, .. } => Some((rows, cols)),
            SlotKind::Full { .. } => None,
        }
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let (b1, b2) = (self.beta1, self.beta2);
        for idx in 0..params.len() {
            let wd = params[idx].data_mut();
            let gd = grads[idx].data();
            {
                let (store, id) = (&self.store, self.mom_ids[idx]);
                self.mom_buf.with_vec(|v| store.read_into(id, v));
            }
            let mom = &mut self.mom_buf;
            let kind = self.kinds[idx];
            match kind {
                SlotKind::Factored { vr: vr_id, vc: vc_id, rows, cols } => {
                    let (m, n) = (rows, cols);
                    {
                        let store = &self.store;
                        self.stat_a.with_vec(|v| store.read_into(vr_id, v));
                        self.stat_b.with_vec(|v| store.read_into(vc_id, v));
                    }
                    let vr = &mut self.stat_a;
                    let vc = &mut self.stat_b;
                    // update factored stats: row/col means of g² + eps
                    for i in 0..m {
                        let mut s = 0.0f32;
                        for j in 0..n {
                            let g = gd[i * n + j];
                            s += g * g + EPS;
                        }
                        vr[i] = b2 * vr[i] + (1.0 - b2) * (s / n as f32);
                    }
                    for j in 0..n {
                        let mut s = 0.0f32;
                        for i in 0..m {
                            let g = gd[i * n + j];
                            s += g * g + EPS;
                        }
                        vc[j] = b2 * vc[j] + (1.0 - b2) * (s / m as f32);
                    }
                    let vr_mean: f32 = vr.iter().sum::<f32>() / m as f32;
                    // unclipped update into scratch, accumulate RMS
                    self.scratch.clear();
                    self.scratch.resize(m * n);
                    let mut sumsq = 0.0f32;
                    for i in 0..m {
                        for j in 0..n {
                            let k = i * n + j;
                            let vhat = vr[i] * vc[j] / vr_mean;
                            let u = gd[k] / vhat.sqrt();
                            self.scratch[k] = u;
                            sumsq += u * u;
                        }
                    }
                    let rms = (sumsq / (m * n) as f32).sqrt();
                    let clip = 1.0f32.max(rms);
                    for k in 0..m * n {
                        let u = self.scratch[k] / clip;
                        mom[k] = b1 * mom[k] + (1.0 - b1) * u;
                        wd[k] -= lr * mom[k];
                    }
                    self.store.write(vr_id, vr);
                    self.store.write(vc_id, vc);
                }
                SlotKind::Full { v: v_id } => {
                    {
                        let store = &self.store;
                        self.stat_a.with_vec(|b| store.read_into(v_id, b));
                    }
                    let v = &mut self.stat_a;
                    self.scratch.clear();
                    self.scratch.resize(wd.len());
                    let mut sumsq = 0.0f32;
                    for k in 0..wd.len() {
                        v[k] = b2 * v[k] + (1.0 - b2) * (gd[k] * gd[k] + EPS);
                        let u = gd[k] / v[k].sqrt();
                        self.scratch[k] = u;
                        sumsq += u * u;
                    }
                    let rms = (sumsq / wd.len() as f32).sqrt();
                    let clip = 1.0f32.max(rms);
                    for k in 0..wd.len() {
                        let u = self.scratch[k] / clip;
                        mom[k] = b1 * mom[k] + (1.0 - b1) * u;
                        wd[k] -= lr * mom[k];
                    }
                    self.store.write(v_id, v);
                }
            }
            self.store.write(self.mom_ids[idx], &self.mom_buf);
        }
        // Scratch and dequantize buffers are retained between steps —
        // see the field docs for the resident-memory tradeoff this makes
        // under the per-leaf ParallelStep configuration.
    }

    fn state_floats(&self) -> usize {
        self.store.state_floats()
    }

    fn state_bytes(&self) -> usize {
        self.store.state_bytes()
    }

    fn state_dtype(&self) -> StateDtype {
        self.store.dtype()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for (i, kind) in self.kinds.iter().enumerate() {
            match *kind {
                SlotKind::Factored { vr, vc, rows, cols } => {
                    out.push((i, "vr", Tensor::from_vec(
                        &[rows], self.store.to_vec(vr))));
                    out.push((i, "vc", Tensor::from_vec(
                        &[cols], self.store.to_vec(vc))));
                }
                SlotKind::Full { v } => {
                    out.push((i, "v", Tensor::from_vec(
                        &[self.store.slot_len(v)], self.store.to_vec(v))));
                }
            }
            out.push((i, "mom", Tensor::from_vec(
                &self.specs[i].shape, self.store.to_vec(self.mom_ids[i]))));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) -> anyhow::Result<()> {
        // Mismatch diagnostics name the leaf and its slot kind: a restore
        // from a checkpoint written for a different parameter folding
        // (e.g. a rank-3 leaf saved full-v but expected factored) must say
        // *which* leaf and *what* layout was expected, not just "underrun".
        fn take(it: &mut std::vec::IntoIter<Tensor>, leaf: &str,
                slot: &str, kind: &str, want: usize)
                -> anyhow::Result<Tensor> {
            let t = it.next().ok_or_else(|| anyhow::anyhow!(
                "adafactor state underrun at leaf {leaf:?} slot {slot:?} \
                 (leaf layout: {kind})"))?;
            ensure!(t.len() == want,
                    "adafactor leaf {leaf:?} slot {slot:?}: checkpoint \
                     tensor has {} elements, expected {want} (leaf \
                     layout: {kind})",
                    t.len());
            Ok(t)
        }
        let mut it = state.into_iter();
        for i in 0..self.kinds.len() {
            let kind = self.kinds[i];
            let leaf = self.specs[i].name.clone();
            let expect = kind.describe();
            match kind {
                SlotKind::Factored { vr, vc, rows, cols } => {
                    let tr = take(&mut it, &leaf, "vr", &expect, rows)?;
                    let tc = take(&mut it, &leaf, "vc", &expect, cols)?;
                    self.store.write(vr, tr.data());
                    self.store.write(vc, tc.data());
                }
                SlotKind::Full { v } => {
                    let n = self.store.slot_len(v);
                    let tv = take(&mut it, &leaf, "v", &expect, n)?;
                    self.store.write(v, tv.data());
                }
            }
            let tm = take(&mut it, &leaf, "mom", &expect,
                          self.specs[i].numel())?;
            ensure!(tm.shape() == self.specs[i].shape.as_slice(),
                    "adafactor leaf {leaf:?} momentum: checkpoint shape \
                     {:?} != parameter shape {:?} (leaf layout: {expect})",
                    tm.shape(), self.specs[i].shape);
            self.store.write(self.mom_ids[i], tm.data());
        }
        ensure!(it.next().is_none(), "adafactor state overrun");
        Ok(())
    }

    fn scratch_bytes(&self) -> usize {
        (self.scratch.len() + self.mom_buf.len() + self.stat_a.len()
         + self.stat_b.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn factored_state_is_sublinear() {
        let specs = vec![ParamSpec::new("emb", &[256, 64])];
        let opt = Adafactor::new(&specs, 0.9, 0.98);
        // stats: 256 + 64; momentum: 256*64
        assert_eq!(opt.state_floats(), 256 + 64 + 256 * 64);
    }

    #[test]
    fn update_rms_clipped_to_one() {
        // with zero history a huge gradient's update must have RMS <= 1
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let mut opt = Adafactor::new(&specs, 0.0, 0.5);
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let mut rng = Rng::new(1);
        let g = Tensor::randn(&[4, 4], 100.0, &mut rng);
        opt.step(&mut params, &[g], 1.0);
        let rms = (params[0].sq_norm() / 16.0).sqrt();
        assert!(rms <= 1.0 + 1e-4, "rms {rms}");
    }

    #[test]
    fn rank3_is_folded_to_matrix() {
        let specs = vec![ParamSpec::new("conv", &[3, 3, 8])];
        let opt = Adafactor::new(&specs, 0.9, 0.98);
        assert_eq!(opt.factored_dims(0), Some((9, 8)),
                   "leaf \"conv\" must fold to a (9, 8) factored slot");
        let specs = vec![ParamSpec::new("b", &[8])];
        let opt = Adafactor::new(&specs, 0.9, 0.98);
        assert_eq!(opt.factored_dims(0), None);
    }

    /// Regression (ISSUE 2 satellite; ISSUE 9 turned the panic into an
    /// error): a mismatched restore must name the offending leaf and its
    /// expected slot layout, so a checkpoint saved for a different
    /// folding is diagnosable.
    #[test]
    fn load_state_mismatch_names_leaf_and_kind() {
        let specs = vec![ParamSpec::new("enc0/ffn_w1", &[6, 4])];
        let mut opt = Adafactor::new(&specs, 0.9, 0.98);
        // a full-v style state (one 24-elem v + mom) where factored
        // (vr[6], vc[4], mom) is expected
        let bad = vec![Tensor::zeros(&[24]), Tensor::zeros(&[6, 4])];
        let err = opt.load_state(bad).unwrap_err().to_string();
        assert!(err.contains("leaf \"enc0/ffn_w1\" slot \"vr\""), "{err}");
        assert!(err.contains("factored (vr[6], vc[4])"), "{err}");
    }

    #[test]
    fn state_roundtrip_all_dtypes() {
        let specs = vec![ParamSpec::new("w", &[5, 7]),
                         ParamSpec::new("b", &[7])];
        for dtype in StateDtype::ALL {
            let mut opt = Adafactor::with_dtype(&specs, 0.9, 0.98, dtype);
            let mut rng = Rng::new(11);
            let mut params: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            for _ in 0..3 {
                let grads: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect();
                opt.step(&mut params, &grads, 0.1);
            }
            let saved: Vec<Tensor> =
                opt.state().into_iter().map(|(_, _, t)| t).collect();
            let mut fresh = Adafactor::with_dtype(&specs, 0.9, 0.98, dtype);
            fresh.load_state(saved.clone()).unwrap();
            let restored: Vec<Tensor> =
                fresh.state().into_iter().map(|(_, _, t)| t).collect();
            assert_eq!(saved, restored, "{dtype:?}");
        }
    }
}
