//! Adafactor (Shazeer & Stern) — the sublinear-memory comparator
//! (paper §4 "Comparison with Adafactor").
//!
//! Matrix parameters keep factored row/col second-moment estimates
//! (Θ(m+n) like SM3); vectors fall back to the full second moment.
//! Rank>2 tensors are folded to (Π leading dims, last dim) matrices —
//! Adafactor is matrix-only by construction. Update clipping at RMS 1.0
//! (the reference implementation's d=1.0) and β1 momentum, matching the
//! paper's experimental setup (all methods run with momentum).

use super::{Optimizer, ParamSpec};
use crate::tensor::Tensor;

const EPS: f32 = 1e-30;

enum Slot {
    Factored { vr: Vec<f32>, vc: Vec<f32>, rows: usize, cols: usize },
    Full { v: Vec<f32> },
}

pub struct Adafactor {
    beta1: f32,
    beta2: f32,
    slots: Vec<Slot>,
    mom: Vec<Tensor>,
    /// scratch buffer for the unclipped update (reused across leaves)
    scratch: Vec<f32>,
}

impl Adafactor {
    pub fn new(specs: &[ParamSpec], beta1: f32, beta2: f32) -> Self {
        let slots = specs
            .iter()
            .map(|s| {
                if s.shape.len() >= 2 {
                    let cols = *s.shape.last().unwrap();
                    let rows = s.numel() / cols;
                    Slot::Factored { vr: vec![0.0; rows], vc: vec![0.0; cols],
                                     rows, cols }
                } else {
                    Slot::Full { v: vec![0.0; s.numel()] }
                }
            })
            .collect();
        Self {
            beta1,
            beta2,
            slots,
            mom: specs.iter().map(|s| Tensor::zeros(&s.shape)).collect(),
            scratch: Vec::new(),
        }
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let (b1, b2) = (self.beta1, self.beta2);
        for idx in 0..params.len() {
            let wd = params[idx].data_mut();
            let gd = grads[idx].data();
            let mom = self.mom[idx].data_mut();
            match &mut self.slots[idx] {
                Slot::Factored { vr, vc, rows, cols } => {
                    let (m, n) = (*rows, *cols);
                    // update factored stats: row/col means of g² + eps
                    for i in 0..m {
                        let mut s = 0.0f32;
                        for j in 0..n {
                            let g = gd[i * n + j];
                            s += g * g + EPS;
                        }
                        vr[i] = b2 * vr[i] + (1.0 - b2) * (s / n as f32);
                    }
                    for j in 0..n {
                        let mut s = 0.0f32;
                        for i in 0..m {
                            let g = gd[i * n + j];
                            s += g * g + EPS;
                        }
                        vc[j] = b2 * vc[j] + (1.0 - b2) * (s / m as f32);
                    }
                    let vr_mean: f32 = vr.iter().sum::<f32>() / m as f32;
                    // unclipped update into scratch, accumulate RMS
                    self.scratch.clear();
                    self.scratch.resize(m * n, 0.0);
                    let mut sumsq = 0.0f32;
                    for i in 0..m {
                        for j in 0..n {
                            let k = i * n + j;
                            let vhat = vr[i] * vc[j] / vr_mean;
                            let u = gd[k] / vhat.sqrt();
                            self.scratch[k] = u;
                            sumsq += u * u;
                        }
                    }
                    let rms = (sumsq / (m * n) as f32).sqrt();
                    let clip = 1.0f32.max(rms);
                    for k in 0..m * n {
                        let u = self.scratch[k] / clip;
                        mom[k] = b1 * mom[k] + (1.0 - b1) * u;
                        wd[k] -= lr * mom[k];
                    }
                }
                Slot::Full { v } => {
                    self.scratch.clear();
                    self.scratch.resize(wd.len(), 0.0);
                    let mut sumsq = 0.0f32;
                    for k in 0..wd.len() {
                        v[k] = b2 * v[k] + (1.0 - b2) * (gd[k] * gd[k] + EPS);
                        let u = gd[k] / v[k].sqrt();
                        self.scratch[k] = u;
                        sumsq += u * u;
                    }
                    let rms = (sumsq / wd.len() as f32).sqrt();
                    let clip = 1.0f32.max(rms);
                    for k in 0..wd.len() {
                        let u = self.scratch[k] / clip;
                        mom[k] = b1 * mom[k] + (1.0 - b1) * u;
                        wd[k] -= lr * mom[k];
                    }
                }
            }
        }
        // Release the scratch between steps: the resize above zero-fills
        // either way, so retained capacity buys nothing, and ParallelStep
        // holds one Adafactor per leaf — kept buffers would sum to Θ(d)
        // resident scratch in a crate whose headline metric is optimizer
        // memory.
        self.scratch = Vec::new();
    }

    fn state_floats(&self) -> usize {
        let stats: usize = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Factored { vr, vc, .. } => vr.len() + vc.len(),
                Slot::Full { v } => v.len(),
            })
            .sum();
        stats + self.mom.iter().map(Tensor::len).sum::<usize>()
    }

    fn state(&self) -> Vec<(usize, &'static str, Tensor)> {
        let mut out = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            match s {
                Slot::Factored { vr, vc, .. } => {
                    out.push((i, "vr", Tensor::from_vec(&[vr.len()], vr.clone())));
                    out.push((i, "vc", Tensor::from_vec(&[vc.len()], vc.clone())));
                }
                Slot::Full { v } => {
                    out.push((i, "v", Tensor::from_vec(&[v.len()], v.clone())));
                }
            }
            out.push((i, "mom", self.mom[i].clone()));
        }
        out
    }

    fn load_state(&mut self, state: Vec<Tensor>) {
        let mut it = state.into_iter();
        for (i, s) in self.slots.iter_mut().enumerate() {
            match s {
                Slot::Factored { vr, vc, .. } => {
                    vr.copy_from_slice(it.next().expect("underrun").data());
                    vc.copy_from_slice(it.next().expect("underrun").data());
                }
                Slot::Full { v } => {
                    v.copy_from_slice(it.next().expect("underrun").data());
                }
            }
            self.mom[i] = it.next().expect("underrun");
        }
        assert!(it.next().is_none());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn factored_state_is_sublinear() {
        let specs = vec![ParamSpec::new("emb", &[256, 64])];
        let opt = Adafactor::new(&specs, 0.9, 0.98);
        // stats: 256 + 64; momentum: 256*64
        assert_eq!(opt.state_floats(), 256 + 64 + 256 * 64);
    }

    #[test]
    fn update_rms_clipped_to_one() {
        // with zero history a huge gradient's update must have RMS <= 1
        let specs = vec![ParamSpec::new("w", &[4, 4])];
        let mut opt = Adafactor::new(&specs, 0.0, 0.5);
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let mut rng = Rng::new(1);
        let g = Tensor::randn(&[4, 4], 100.0, &mut rng);
        opt.step(&mut params, &[g], 1.0);
        let rms = (params[0].sq_norm() / 16.0).sqrt();
        assert!(rms <= 1.0 + 1e-4, "rms {rms}");
    }

    #[test]
    fn rank3_is_folded_to_matrix() {
        let specs = vec![ParamSpec::new("conv", &[3, 3, 8])];
        let opt = Adafactor::new(&specs, 0.9, 0.98);
        match &opt.slots[0] {
            Slot::Factored { rows, cols, .. } => {
                assert_eq!((*rows, *cols), (9, 8));
            }
            _ => panic!("expected factored slot"),
        }
    }
}
