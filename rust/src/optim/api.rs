//! The typed, composable optimizer-construction API (DESIGN.md §11).
//!
//! Replaces the telescoping free-function constructors (`build` →
//! `build_with_dtype` → `build_with_opts`, six positional arguments and
//! counting) with a builder: an [`OptimSpec`] carries a typed [`Method`]
//! (per-method hyperparameters — Adam's `eps` exists only where Adam
//! does), shared [`StateOpts`] (slot storage precision + streaming
//! tile), a chain of [`UpdateTransform`] stages, per-parameter
//! [`GroupSpec`] overrides, and the execution plan (`threads`,
//! [`SplitPolicy`]). `build` resolves everything against the parameter
//! list and returns one `Box<dyn Optimizer>`:
//!
//! ```no_run
//! use sm3::optim::{AdamHp, Method, OptimSpec, ParamSpec, GroupSpec};
//! let specs = [ParamSpec::new("embed", &[1024, 64]),
//!              ParamSpec::new("ln_bias", &[64])];
//! let opt = OptimSpec::new(Method::Adam(AdamHp { eps: 1e-9, ..AdamHp::default() }))
//!     .clip_by_global_norm(1.0)
//!     .weight_decay(0.01)
//!     .group(GroupSpec::new("*bias*").weight_decay(0.0))
//!     .threads(4)
//!     .build(&specs)
//!     .unwrap();
//! # drop(opt);
//! ```
//!
//! Construction rules (all bitwise-stable, property-tested):
//!
//! * `threads == 1` and uniform LR scales ⇒ one serial registry
//!   optimizer — the exact seed construction, same checkpoint layout.
//! * `threads > 1` *or* any per-group LR scale ⇒ a
//!   [`ParallelStep`] engine (per-leaf sub-optimizers; `threads = 1`
//!   runs them inline with no spawns). Per-leaf LR scales are applied by
//!   the engine as `lr · s_i`, leaving the update arithmetic otherwise
//!   untouched.
//! * Any gradient transform or weight decay ⇒ the engine is wrapped in a
//!   [`Pipeline`] (see [`super::transform`] for the stage order and the
//!   two-phase global-norm reduce).

use super::backend::Backend;
use super::kernel;
use super::parallel::{ParallelStep, SplitPolicy};
use super::qstate::StateDtype;
use super::transform::{Pipeline, UpdateTransform};
use super::{Adafactor, Adagrad, Adam, Optimizer, ParamSpec, SgdMomentum,
            Sm3, Sm3Variant};
use crate::pool::Pool;
use anyhow::{bail, ensure, Result};

/// Adam hyperparameters (Kingma & Ba). `eps` was hard-pinned to `1e-8`
/// inside the legacy constructors; it is a first-class field here.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdamHp {
    /// First-moment decay β₁.
    pub beta1: f32,
    /// Second-moment decay β₂.
    pub beta2: f32,
    /// Denominator stabilizer ε (added to `sqrt(v̂)`).
    pub eps: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.98, eps: 1e-8 }
    }
}

/// SM3 hyperparameters (paper §3–4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sm3Hp {
    /// Heavy-ball momentum β₁.
    pub beta1: f32,
    /// SM3-I or SM3-II (the tighter variant; registry name "sm3").
    pub variant: Sm3Variant,
}

impl Default for Sm3Hp {
    fn default() -> Self {
        Self { beta1: 0.9, variant: Sm3Variant::II }
    }
}

/// Adagrad hyperparameters (paper Eq. 1–2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdagradHp {
    /// Heavy-ball momentum β₁.
    pub beta1: f32,
}

impl Default for AdagradHp {
    fn default() -> Self {
        Self { beta1: 0.9 }
    }
}

/// Adafactor hyperparameters (Shazeer & Stern).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdafactorHp {
    /// Momentum β₁ (the paper's experiments run all methods with it).
    pub beta1: f32,
    /// Factored second-moment decay β₂.
    pub beta2: f32,
}

impl Default for AdafactorHp {
    fn default() -> Self {
        Self { beta1: 0.9, beta2: 0.98 }
    }
}

/// SGD-with-momentum hyperparameters (the non-adaptive baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SgdmHp {
    /// Heavy-ball momentum β₁.
    pub beta1: f32,
}

impl Default for SgdmHp {
    fn default() -> Self {
        Self { beta1: 0.9 }
    }
}

/// A typed optimizer choice: the method plus exactly its own
/// hyperparameters — no more forcing `beta2` on SM3 or `eps` on SGD.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Adam — the 2·d-state baseline of Tables 1–2.
    Adam(AdamHp),
    /// SM3 (I or II per [`Sm3Hp::variant`]) — the paper's method.
    Sm3(Sm3Hp),
    /// Adagrad with momentum — the linear-memory comparator.
    Adagrad(AdagradHp),
    /// Adafactor — the sublinear-memory comparator.
    Adafactor(AdafactorHp),
    /// SGD with heavy-ball momentum.
    SgdMomentum(SgdmHp),
}

impl Method {
    /// Typed method for a registry name (`optim::ALL`), with the
    /// repository-default hyperparameters.
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "adam" => Method::Adam(AdamHp::default()),
            "sm3" => Method::Sm3(Sm3Hp::default()),
            "sm3i" => Method::Sm3(Sm3Hp { variant: Sm3Variant::I,
                                          ..Sm3Hp::default() }),
            "adagrad" => Method::Adagrad(AdagradHp::default()),
            "adafactor" => Method::Adafactor(AdafactorHp::default()),
            "sgdm" => Method::SgdMomentum(SgdmHp::default()),
            other => bail!("unknown optimizer {other:?} (known: {:?})",
                           super::ALL),
        })
    }

    /// The registry/artifact name this method builds ("sm3", "adam", …).
    pub fn registry_name(&self) -> &'static str {
        match self {
            Method::Adam(_) => "adam",
            Method::Sm3(hp) => match hp.variant {
                Sm3Variant::II => "sm3",
                Sm3Variant::I => "sm3i",
            },
            Method::Adagrad(_) => "adagrad",
            Method::Adafactor(_) => "adafactor",
            Method::SgdMomentum(_) => "sgdm",
        }
    }

    /// Set β₁ (every method has one).
    pub fn set_beta1(&mut self, beta1: f32) {
        match self {
            Method::Adam(hp) => hp.beta1 = beta1,
            Method::Sm3(hp) => hp.beta1 = beta1,
            Method::Adagrad(hp) => hp.beta1 = beta1,
            Method::Adafactor(hp) => hp.beta1 = beta1,
            Method::SgdMomentum(hp) => hp.beta1 = beta1,
        }
    }

    /// Set β₂ where the method defines one (Adam, Adafactor); a no-op
    /// elsewhere — the typed structs are the place to be strict, this
    /// setter exists for the name-based config bridge.
    pub fn set_beta2(&mut self, beta2: f32) {
        match self {
            Method::Adam(hp) => hp.beta2 = beta2,
            Method::Adafactor(hp) => hp.beta2 = beta2,
            _ => {}
        }
    }

    /// Set Adam's ε; a no-op for every other method (same rationale as
    /// [`Method::set_beta2`]).
    pub fn set_eps(&mut self, eps: f32) {
        if let Method::Adam(hp) = self {
            hp.eps = eps;
        }
    }

    /// Does this method have an ε hyperparameter? The config layer asks
    /// this to reject an `[optim] eps` override that [`Method::set_eps`]
    /// would silently drop.
    pub fn has_eps(&self) -> bool {
        matches!(self, Method::Adam(_))
    }

    /// Can this method's update of a rank-`rank` leaf be expressed as a
    /// per-element kernel (and therefore sharded *inside* the leaf and
    /// streamed through the chunked drivers)?
    ///
    /// This is the registry's capability declaration — the match is
    /// deliberately exhaustive (no `_` arm), so adding a [`Method`]
    /// variant without declaring its chunking capability is a compile
    /// error rather than a silent fall-through to the leaf-granular
    /// path (a perf trap, not a correctness one). The name-based
    /// [`kernel::elementwise`] is a thin bridge over this method.
    ///
    /// Adagrad, Adam and SGD+momentum update every element independently
    /// at any rank. SM3 is element-wise only under the singleton cover
    /// (rank ≤ 1 — where it coincides with Adagrad); its matrix/tensor
    /// covers fold each `nu` into row/col maxima. Adafactor is never
    /// element-wise: even its full-`v` vector path ends in a whole-leaf
    /// RMS clip.
    pub fn elementwise_at_rank(&self, rank: usize) -> bool {
        match self {
            Method::Adam(_) | Method::Adagrad(_) | Method::SgdMomentum(_) => {
                true
            }
            Method::Sm3(_) => rank <= 1,
            Method::Adafactor(_) => false,
        }
    }

    /// β₁ of the method (for validation and introspection).
    pub fn beta1(&self) -> f32 {
        match self {
            Method::Adam(hp) => hp.beta1,
            Method::Sm3(hp) => hp.beta1,
            Method::Adagrad(hp) => hp.beta1,
            Method::Adafactor(hp) => hp.beta1,
            Method::SgdMomentum(hp) => hp.beta1,
        }
    }

    /// Validate the method's own hyperparameters.
    pub fn validate(&self) -> Result<()> {
        ensure!((0.0..1.0).contains(&self.beta1()),
                "{}: beta1 must be in [0, 1), got {}",
                self.registry_name(), self.beta1());
        match self {
            Method::Adam(hp) => {
                ensure!((0.0..1.0).contains(&hp.beta2),
                        "adam: beta2 must be in [0, 1), got {}", hp.beta2);
                ensure!(hp.eps.is_finite() && hp.eps > 0.0,
                        "adam: eps must be finite and > 0, got {}", hp.eps);
            }
            Method::Adafactor(hp) => {
                ensure!((0.0..1.0).contains(&hp.beta2),
                        "adafactor: beta2 must be in [0, 1), got {}",
                        hp.beta2);
            }
            _ => {}
        }
        Ok(())
    }

    /// Construct one serial optimizer instance over `specs` (the leaf
    /// factory `ParallelStep` and the legacy shims share). `opts.chunk`
    /// must already be validated ([`kernel::check_chunk`]). When `pool`
    /// is `Some`, state slots and working scratch lease from it
    /// (bitwise identical either way — the pool is a placement knob,
    /// DESIGN.md §16).
    pub fn build_serial(&self, specs: &[ParamSpec], opts: &StateOpts,
                        pool: Option<&Pool>) -> Box<dyn Optimizer> {
        match self {
            Method::Adam(hp) => {
                let mut o = match pool {
                    Some(p) => Adam::with_opts_in(specs, hp.beta1, hp.beta2,
                                                  hp.eps, opts.dtype,
                                                  opts.chunk, p),
                    None => Adam::with_opts(specs, hp.beta1, hp.beta2,
                                            hp.eps, opts.dtype, opts.chunk),
                };
                o.set_backend(opts.backend);
                Box::new(o)
            }
            Method::Sm3(hp) => {
                let mut o = match pool {
                    Some(p) => Sm3::with_opts_in(specs, hp.variant, hp.beta1,
                                                 opts.dtype, opts.chunk, p),
                    None => Sm3::with_opts(specs, hp.variant, hp.beta1,
                                           opts.dtype, opts.chunk),
                };
                o.set_backend(opts.backend);
                Box::new(o)
            }
            Method::Adagrad(hp) => {
                let mut o = match pool {
                    Some(p) => Adagrad::with_opts_in(specs, hp.beta1,
                                                     opts.dtype, opts.chunk,
                                                     p),
                    None => Adagrad::with_opts(specs, hp.beta1, opts.dtype,
                                               opts.chunk),
                };
                o.set_backend(opts.backend);
                Box::new(o)
            }
            Method::Adafactor(hp) => {
                // leaf-granular two-pass update: no streaming tile
                let mut o = match pool {
                    Some(p) => Adafactor::with_dtype_in(specs, hp.beta1,
                                                        hp.beta2, opts.dtype,
                                                        p),
                    None => Adafactor::with_dtype(specs, hp.beta1, hp.beta2,
                                                  opts.dtype),
                };
                o.set_backend(opts.backend);
                Box::new(o)
            }
            Method::SgdMomentum(hp) => {
                let mut o = match pool {
                    Some(p) => SgdMomentum::with_opts_in(specs, hp.beta1,
                                                         opts.dtype,
                                                         opts.chunk, p),
                    None => SgdMomentum::with_opts(specs, hp.beta1,
                                                   opts.dtype, opts.chunk),
                };
                o.set_backend(opts.backend);
                Box::new(o)
            }
        }
    }
}

/// Shared optimizer-state storage options, orthogonal to the method.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StateOpts {
    /// Slot storage precision (config `state_dtype`, DESIGN.md §10).
    pub dtype: StateDtype,
    /// Streaming tile in elements — a positive multiple of the q8 block
    /// (config `step_chunk`; traversal granularity only, bitwise-stable).
    pub chunk: usize,
    /// Kernel backend the hot loops dispatch to (config `kernel_backend`,
    /// DESIGN.md §13; every backend is bitwise identical, so this is a
    /// pure performance knob).
    pub backend: Backend,
}

impl Default for StateOpts {
    fn default() -> Self {
        Self { dtype: StateDtype::F32, chunk: kernel::DEFAULT_CHUNK,
               backend: Backend::default() }
    }
}

/// A parameter group: every leaf whose name matches `pattern` gets this
/// group's LR scale and (optionally) weight-decay override.
///
/// Patterns without `*` match as **name prefixes** ("l0/" covers the
/// whole layer); patterns with `*` are globs ("*bias*", "*/ln_*"). When
/// several groups match one leaf, the most specific wins — most literal
/// (non-`*`) characters; ties go to the later group. A group matching
/// zero parameters is a build error (it is always a config typo).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupSpec {
    /// Name-prefix or `*`-glob over [`ParamSpec::name`].
    pub pattern: String,
    /// Multiplies the post-schedule LR for matched leaves (default 1).
    pub lr_scale: f32,
    /// Overrides the pipeline's base weight-decay rate for matched
    /// leaves (`Some(0.0)` = "no decay here", the bias/LayerNorm case).
    pub weight_decay: Option<f32>,
}

impl GroupSpec {
    /// A group matching `pattern` with no overrides yet.
    pub fn new(pattern: impl Into<String>) -> Self {
        Self { pattern: pattern.into(), lr_scale: 1.0, weight_decay: None }
    }

    /// Set the group's LR scale.
    pub fn lr_scale(mut self, s: f32) -> Self {
        self.lr_scale = s;
        self
    }

    /// Set the group's weight-decay override.
    pub fn weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = Some(wd);
        self
    }
}

/// Does `pat` (prefix, or glob when it contains `*`) match `name`?
pub(crate) fn pattern_matches(pat: &str, name: &str) -> bool {
    if !pat.contains('*') {
        return name.starts_with(pat);
    }
    let parts: Vec<&str> = pat.split('*').collect();
    let mut pos = 0usize;
    for (k, part) in parts.iter().enumerate() {
        if part.is_empty() {
            continue;
        }
        if k == 0 {
            if !name.starts_with(part) {
                return false;
            }
            pos = part.len();
        } else if k == parts.len() - 1 {
            return name.len() >= pos + part.len()
                && name[pos..].ends_with(part);
        } else {
            match name[pos..].find(part) {
                Some(i) => pos += i + part.len(),
                None => return false,
            }
        }
    }
    true
}

/// Specificity of a pattern: its literal (non-`*`) character count.
fn specificity(pat: &str) -> usize {
    pat.chars().filter(|&c| c != '*').count()
}

/// The typed, composable optimizer builder. See the module docs for the
/// grammar and [`OptimSpec::build`] for the resolution rules.
#[derive(Clone, Debug)]
pub struct OptimSpec {
    method: Method,
    state: StateOpts,
    transforms: Vec<UpdateTransform>,
    groups: Vec<GroupSpec>,
    threads: usize,
    policy: SplitPolicy,
    /// memory pool state slots and scratch lease from (`None` = plain
    /// heap Vecs, the pre-pool construction; bitwise identical)
    pool: Option<Pool>,
}

impl OptimSpec {
    /// A spec for a typed method with default state options, no
    /// transforms, no groups, serial execution.
    pub fn new(method: Method) -> Self {
        Self {
            method,
            state: StateOpts::default(),
            transforms: Vec::new(),
            groups: Vec::new(),
            threads: 1,
            policy: SplitPolicy::IntraLeaf,
            pool: None,
        }
    }

    /// A spec from a registry name with default hyperparameters — the
    /// bridge from configs and CLI flags to the typed world.
    pub fn named(name: &str) -> Result<Self> {
        Ok(Self::new(Method::from_name(name)?))
    }

    /// The method (for introspection).
    pub fn method(&self) -> &Method {
        &self.method
    }

    /// Set β₁ on the method.
    pub fn beta1(mut self, beta1: f32) -> Self {
        self.method.set_beta1(beta1);
        self
    }

    /// Set β₂ where the method has one (no-op elsewhere — see
    /// [`Method::set_beta2`]).
    pub fn beta2(mut self, beta2: f32) -> Self {
        self.method.set_beta2(beta2);
        self
    }

    /// Set Adam's ε (no-op for other methods).
    pub fn eps(mut self, eps: f32) -> Self {
        self.method.set_eps(eps);
        self
    }

    /// Set the state-slot storage precision.
    pub fn state_dtype(mut self, dtype: StateDtype) -> Self {
        self.state.dtype = dtype;
        self
    }

    /// Set the streaming tile (positive multiple of the q8 block).
    pub fn step_chunk(mut self, chunk: usize) -> Self {
        self.state.chunk = chunk;
        self
    }

    /// Set the kernel backend the hot loops dispatch to (bitwise
    /// identical across backends — a pure performance knob).
    pub fn kernel_backend(mut self, backend: Backend) -> Self {
        self.state.backend = backend;
        self
    }

    /// Shard the update across host threads (1 = serial; results are
    /// bitwise identical at any count — `optim::parallel`).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// How `ParallelStep` may divide leaves across workers.
    pub fn split_policy(mut self, policy: SplitPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Lease every state slot and working buffer from `pool` (the
    /// unified memory-pool runtime, DESIGN.md §16). Clones the handle —
    /// the pool is shared, occupancy is visible through the original.
    /// Bitwise identical to the unpooled construction.
    pub fn pool(mut self, pool: &Pool) -> Self {
        self.pool = Some(pool.clone());
        self
    }

    /// Append a transform stage (stages run in chained order).
    pub fn transform(mut self, t: UpdateTransform) -> Self {
        self.transforms.push(t);
        self
    }

    /// Append a `clip_by_global_norm(c)` stage.
    pub fn clip_by_global_norm(self, c: f32) -> Self {
        self.transform(UpdateTransform::ClipByGlobalNorm(c))
    }

    /// Append a `clip_by_value(c)` stage.
    pub fn clip_by_value(self, c: f32) -> Self {
        self.transform(UpdateTransform::ClipByValue(c))
    }

    /// Enable decoupled (AdamW-style) weight decay at base rate `wd`.
    pub fn weight_decay(self, wd: f32) -> Self {
        self.transform(UpdateTransform::DecoupledWeightDecay(wd))
    }

    /// Add a parameter group (see [`GroupSpec`]).
    pub fn group(mut self, g: GroupSpec) -> Self {
        self.groups.push(g);
        self
    }

    /// Validate everything that can be checked without a parameter list
    /// (group matching needs the specs and happens in [`OptimSpec::build`]).
    pub fn validate(&self) -> Result<()> {
        self.method.validate()?;
        kernel::check_chunk(self.state.chunk)?;
        ensure!(self.threads >= 1, "threads must be >= 1 (1 = serial)");
        let mut decays = 0usize;
        for t in &self.transforms {
            match *t {
                UpdateTransform::ClipByValue(c) => {
                    ensure!(c.is_finite() && c > 0.0,
                            "clip_by_value threshold must be finite and \
                             > 0, got {c}");
                }
                UpdateTransform::ClipByGlobalNorm(c) => {
                    ensure!(c.is_finite() && c > 0.0,
                            "clip_by_global_norm threshold must be finite \
                             and > 0, got {c}");
                }
                UpdateTransform::DecoupledWeightDecay(w) => {
                    ensure!(w.is_finite() && w >= 0.0,
                            "weight_decay must be finite and >= 0, got {w}");
                    decays += 1;
                }
                UpdateTransform::Identity => {}
            }
        }
        ensure!(decays <= 1,
                "at most one weight_decay stage (got {decays}); use param \
                 groups for per-leaf rates");
        for g in &self.groups {
            ensure!(!g.pattern.is_empty(), "group pattern must be non-empty");
            ensure!(g.lr_scale.is_finite() && g.lr_scale > 0.0,
                    "group {:?}: lr_scale must be finite and > 0, got {}",
                    g.pattern, g.lr_scale);
            if let Some(w) = g.weight_decay {
                ensure!(w.is_finite() && w >= 0.0,
                        "group {:?}: weight_decay must be finite and >= 0, \
                         got {w}", g.pattern);
            }
        }
        Ok(())
    }

    /// Resolve the groups against a parameter list into per-leaf
    /// `(weight_decay, lr_scale)` vectors. Most-specific match wins;
    /// a group matching zero leaves is an error.
    pub fn resolve_groups(&self, specs: &[ParamSpec])
                          -> Result<(Vec<f32>, Vec<f32>)> {
        let base_wd = self
            .transforms
            .iter()
            .find_map(|t| match t {
                UpdateTransform::DecoupledWeightDecay(w) => Some(*w),
                _ => None,
            })
            .unwrap_or(0.0);
        let mut wd = vec![base_wd; specs.len()];
        let mut scale = vec![1.0f32; specs.len()];
        if self.groups.is_empty() {
            return Ok((wd, scale));
        }
        let mut matched = vec![0usize; self.groups.len()];
        for (i, s) in specs.iter().enumerate() {
            let mut best: Option<(usize, usize)> = None; // (specificity, gi)
            for (gi, g) in self.groups.iter().enumerate() {
                if pattern_matches(&g.pattern, &s.name) {
                    matched[gi] += 1;
                    let spec_len = specificity(&g.pattern);
                    // >= : the later of two equally specific groups wins
                    if best.map_or(true, |(b, _)| spec_len >= b) {
                        best = Some((spec_len, gi));
                    }
                }
            }
            if let Some((_, gi)) = best {
                let g = &self.groups[gi];
                scale[i] = g.lr_scale;
                if let Some(w) = g.weight_decay {
                    wd[i] = w;
                }
            }
        }
        for (g, &m) in self.groups.iter().zip(&matched) {
            ensure!(m > 0,
                    "param group {:?} matches zero parameters (leaves: \
                     {:?})", g.pattern,
                    specs.iter().map(|s| s.name.as_str())
                        .collect::<Vec<_>>());
        }
        Ok((wd, scale))
    }

    /// Build the optimizer over `specs`. See the module docs for which
    /// engine (serial / `ParallelStep`) and wrapper ([`Pipeline`]) the
    /// resolved spec produces.
    pub fn build(&self, specs: &[ParamSpec]) -> Result<Box<dyn Optimizer>> {
        self.validate()?;
        let (wd, scale) = self.resolve_groups(specs)?;
        let uniform_scale = scale.iter().all(|&s| s == 1.0);
        let inner: Box<dyn Optimizer> = if self.threads > 1 || !uniform_scale
        {
            let (method, state) = (self.method, self.state);
            let pool = self.pool.clone();
            let mut engine = ParallelStep::with_leaf_factory(
                specs, self.threads, self.policy,
                |s| method.elementwise_at_rank(s.shape.len()),
                |s| Ok(method.build_serial(std::slice::from_ref(s), &state,
                                           pool.as_ref())),
            )?;
            if let Some(p) = &self.pool {
                engine.set_pool(p.clone());
            }
            if !uniform_scale {
                engine.set_lr_scales(&scale)?;
            }
            Box::new(engine)
        } else {
            self.method.build_serial(specs, &self.state,
                                     self.pool.as_ref())
        };
        let stages: Vec<UpdateTransform> = self
            .transforms
            .iter()
            .filter(|t| !matches!(t, UpdateTransform::Identity))
            .cloned()
            .collect();
        let needs_pipeline = stages.iter().any(UpdateTransform::is_grad_stage)
            || wd.iter().any(|&w| w != 0.0);
        Ok(if needs_pipeline {
            let mut pipe = Pipeline::with_overrides(inner, specs, stages, wd,
                                                    scale, self.threads)?;
            pipe.set_backend(self.state.backend);
            Box::new(pipe)
        } else {
            inner
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn specs() -> Vec<ParamSpec> {
        vec![ParamSpec::new("embed", &[30, 8]),
             ParamSpec::new("l0/w", &[8, 8]),
             ParamSpec::new("l0/bias", &[8]),
             ParamSpec::new("l0/ln_scale", &[8]),
             ParamSpec::new("l1/w", &[8, 4]),
             ParamSpec::new("l1/bias", &[4])]
    }

    #[test]
    fn pattern_matching_semantics() {
        // no '*': name-prefix
        assert!(pattern_matches("l0/", "l0/w"));
        assert!(pattern_matches("l0/w", "l0/w"));
        assert!(!pattern_matches("l0/", "l1/w"));
        // globs
        assert!(pattern_matches("*bias*", "l0/bias"));
        assert!(pattern_matches("*/ln_*", "l0/ln_scale"));
        assert!(!pattern_matches("*/ln_*", "ln_scale"));
        assert!(pattern_matches("*", "anything"));
        assert!(pattern_matches("l*/w", "l1/w"));
        assert!(!pattern_matches("l*/w", "l1/bias"));
        // anchored tail must not reuse head characters
        assert!(!pattern_matches("ab*ba", "aba"));
        assert!(pattern_matches("ab*ba", "abba"));
    }

    /// Satellite: group resolution picks the most-specific match, ties
    /// go to the later group, and the classic "no decay on biases and
    /// LayerNorm" setup resolves as intended.
    #[test]
    fn group_resolution_most_specific_wins() {
        let spec = OptimSpec::named("adam").unwrap()
            .weight_decay(0.01)
            .group(GroupSpec::new("*bias*").weight_decay(0.0))
            .group(GroupSpec::new("*/ln_*").weight_decay(0.0))
            .group(GroupSpec::new("l0/").lr_scale(0.5))
            .group(GroupSpec::new("l0/bias").lr_scale(0.25));
        let specs = specs();
        let (wd, scale) = spec.resolve_groups(&specs).unwrap();
        // embed: no group → base decay, unit scale
        assert_eq!((wd[0], scale[0]), (0.01, 1.0));
        // l0/w: "l0/" (3 literals) beats nothing else → scaled, decayed
        assert_eq!((wd[1], scale[1]), (0.01, 0.5));
        // l0/bias: "l0/bias" (7) beats "*bias*" (4) and "l0/" (3) —
        // most-specific wins, so the decay-off override does NOT apply
        assert_eq!((wd[2], scale[2]), (0.01, 0.25));
        // l0/ln_scale: "*/ln_*" (4) beats "l0/" (3)
        assert_eq!((wd[3], scale[3]), (0.0, 1.0));
        // l1/w: nothing but base
        assert_eq!((wd[4], scale[4]), (0.01, 1.0));
        // l1/bias: "*bias*"
        assert_eq!((wd[5], scale[5]), (0.0, 1.0));
    }

    /// Satellite: a group that matches nothing is a build-time error
    /// naming the pattern.
    #[test]
    fn group_matching_zero_params_errors() {
        let spec = OptimSpec::named("adam").unwrap()
            .group(GroupSpec::new("decoder/*").weight_decay(0.0));
        let err = spec.build(&specs()).unwrap_err();
        assert!(err.to_string().contains("decoder/*"), "{err}");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let specs = specs();
        assert!(OptimSpec::named("nope").is_err());
        assert!(OptimSpec::named("adam").unwrap().eps(0.0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().eps(-1e-8)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().beta1(1.0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().step_chunk(100)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().threads(0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().clip_by_global_norm(0.0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().clip_by_value(-1.0)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap().weight_decay(-0.1)
            .build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap()
            .weight_decay(0.1).weight_decay(0.2).build(&specs).is_err());
        assert!(OptimSpec::named("adam").unwrap()
            .group(GroupSpec::new("embed").lr_scale(0.0))
            .build(&specs).is_err());
        // identity-only spec builds the bare optimizer
        assert!(OptimSpec::named("adam").unwrap()
            .transform(UpdateTransform::Identity).build(&specs).is_ok());
    }

    #[test]
    fn method_names_roundtrip() {
        for name in crate::optim::ALL {
            let m = Method::from_name(name).unwrap();
            assert_eq!(m.registry_name(), *name);
        }
        assert!(Method::from_name("adamw").is_err());
    }

    /// Satellite (ISSUE 6): every registry entry declares its chunking
    /// capability explicitly through [`Method::elementwise_at_rank`]
    /// (the match is exhaustive, so a new method cannot silently fall to
    /// the leaf-granular path), and the name-based `kernel::elementwise`
    /// bridge agrees with the typed declaration everywhere.
    #[test]
    fn every_registry_method_declares_chunking_capability() {
        for name in crate::optim::ALL {
            let m = Method::from_name(name).unwrap();
            for rank in 0..5 {
                assert_eq!(m.elementwise_at_rank(rank),
                           kernel::elementwise(name, rank),
                           "{name} @ rank {rank}: typed capability and \
                            name bridge disagree");
            }
            // vectors are chunkable for everything but Adafactor
            assert_eq!(m.elementwise_at_rank(1),
                       *name != "adafactor", "{name}");
        }
        // unknown names are never element-wise through the bridge
        assert!(!kernel::elementwise("nope", 1));
    }

    /// The backend knob flows through the builder to the engine without
    /// changing the trajectory (backends are bitwise identical).
    #[test]
    fn kernel_backend_knob_flows_through() {
        use crate::optim::Backend;
        let specs = specs();
        let mut rng = Rng::new(7);
        let init: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let grads: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
            .collect();
        for name in crate::optim::ALL {
            let mut pa = init.clone();
            let mut pb = init.clone();
            let mut scalar = OptimSpec::named(name).unwrap()
                .state_dtype(StateDtype::Q8)
                .kernel_backend(Backend::Scalar)
                .clip_by_global_norm(1.0)
                .build(&specs).unwrap();
            let mut simd = OptimSpec::named(name).unwrap()
                .state_dtype(StateDtype::Q8)
                .kernel_backend(Backend::Simd)
                .clip_by_global_norm(1.0)
                .build(&specs).unwrap();
            for _ in 0..3 {
                scalar.step(&mut pa, &grads, 0.1);
                simd.step(&mut pb, &grads, 0.1);
            }
            for (a, b) in pa.iter().zip(&pb) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}");
                }
            }
        }
    }

    /// The typed path is bitwise identical to the legacy shim for every
    /// registry method (the deprecation contract: the shim is a thin
    /// wrapper, not a second implementation).
    #[test]
    fn typed_build_matches_legacy_shim_bitwise() {
        let specs = specs();
        for name in crate::optim::ALL {
            #[allow(deprecated)]
            let mut legacy =
                crate::optim::build(name, &specs, 0.9, 0.98).unwrap();
            let mut typed =
                OptimSpec::named(name).unwrap().build(&specs).unwrap();
            let mut rng = Rng::new(11);
            let init: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let mut pa = init.clone();
            let mut pb = init;
            for _ in 0..3 {
                let grads: Vec<Tensor> = specs
                    .iter()
                    .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                    .collect();
                legacy.step(&mut pa, &grads, 0.1);
                typed.step(&mut pb, &grads, 0.1);
            }
            for (a, b) in pa.iter().zip(&pb) {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}");
                }
            }
        }
    }

    /// Per-group LR scaling: a scaled leaf follows exactly the
    /// trajectory of a bare single-leaf optimizer stepped at `lr·s`.
    #[test]
    fn group_lr_scale_scales_the_leaf_lr() {
        let specs = vec![ParamSpec::new("w", &[6, 4]),
                         ParamSpec::new("b", &[20])];
        let mut scaled = OptimSpec::named("adam").unwrap()
            .group(GroupSpec::new("b").lr_scale(0.5))
            .build(&specs).unwrap();
        // reference: each leaf as its own bare optimizer at its own lr
        let mut ref_w = OptimSpec::named("adam").unwrap()
            .build(&specs[..1]).unwrap();
        let mut ref_b = OptimSpec::named("adam").unwrap()
            .build(&specs[1..]).unwrap();
        let mut rng = Rng::new(3);
        let mut pa: Vec<Tensor> = specs
            .iter()
            .map(|s| Tensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        let mut pw = vec![pa[0].clone()];
        let mut pb = vec![pa[1].clone()];
        for _ in 0..3 {
            let grads: Vec<Tensor> = specs
                .iter()
                .map(|s| Tensor::randn(&s.shape, 1.0, &mut rng))
                .collect();
            scaled.step(&mut pa, &grads, 0.1);
            ref_w.step(&mut pw, &grads[..1], 0.1);
            ref_b.step(&mut pb, &grads[1..], 0.1 * 0.5);
        }
        for (x, y) in pa[0].data().iter().zip(pw[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "unscaled leaf drifted");
        }
        for (x, y) in pa[1].data().iter().zip(pb[0].data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "scaled leaf != lr*0.5");
        }
    }

    /// Builder knobs reach the engine: dtype, chunk, threads, policy.
    #[test]
    fn builder_knobs_flow_through() {
        let specs = specs();
        let opt = OptimSpec::named("adam").unwrap()
            .state_dtype(StateDtype::Q8)
            .step_chunk(128)
            .threads(3)
            .build(&specs).unwrap();
        assert_eq!(opt.state_dtype(), StateDtype::Q8);
        assert_eq!(opt.name(), "adam");
        #[allow(deprecated)]
        let serial = crate::optim::build_with_dtype(
            "adam", &specs, 0.9, 0.98, StateDtype::Q8).unwrap();
        assert_eq!(opt.state_floats(), serial.state_floats());
        assert_eq!(opt.state_bytes(), serial.state_bytes());
    }
}
