//! The *abstract* SM3 algorithms over arbitrary covers (paper §3).
//!
//! The production path (`optim::sm3`) hard-codes the co-dimension-1 cover
//! for speed. This module implements Algorithm SM3-I and SM3-II verbatim
//! over an explicit cover `{S_r}` of flat parameter indices — exactly the
//! pseudocode — so that
//!   * property tests can check Claim 2 / Proposition 3 on *arbitrary*
//!     covers (overlapping, nested, singleton, full);
//!   * the co-dim-1 fast path can be differentially tested against the
//!     abstract algorithm on the equivalent row/col cover;
//!   * the `Diag` cover reproduces Adagrad exactly, as the paper states.

use super::safe_rsqrt;
use crate::tensor::Tensor;

/// A cover of `[d]`: a list of non-empty index sets whose union is `[d]`.
#[derive(Clone, Debug)]
pub struct Cover {
    /// the cover sets S_r (non-empty, union = [d])
    pub sets: Vec<Vec<usize>>,
    /// inverse index: for each i, which sets contain it
    covering: Vec<Vec<usize>>,
    d: usize,
}

impl Cover {
    /// Build a cover of `[d]` from its sets, validating non-emptiness
    /// and coverage.
    pub fn new(d: usize, sets: Vec<Vec<usize>>) -> Self {
        assert!(!sets.is_empty(), "cover must be non-empty");
        let mut covering = vec![Vec::new(); d];
        for (r, s) in sets.iter().enumerate() {
            assert!(!s.is_empty(), "cover sets must be non-empty");
            for &i in s {
                assert!(i < d, "index {i} out of range {d}");
                covering[i].push(r);
            }
        }
        for (i, c) in covering.iter().enumerate() {
            assert!(!c.is_empty(), "index {i} not covered");
        }
        Self { sets, covering, d }
    }

    /// Singleton cover {{0}, {1}, ...} — SM3 == Adagrad.
    pub fn diag(d: usize) -> Self {
        Self::new(d, (0..d).map(|i| vec![i]).collect())
    }

    /// One set covering everything — maximal compression.
    pub fn full(d: usize) -> Self {
        Self::new(d, vec![(0..d).collect()])
    }

    /// Rows+columns of an m×n matrix flattened row-major — the paper's
    /// practical cover.
    pub fn rows_cols(m: usize, n: usize) -> Self {
        let mut sets = Vec::with_capacity(m + n);
        for i in 0..m {
            sets.push((0..n).map(|j| i * n + j).collect());
        }
        for j in 0..n {
            sets.push((0..m).map(|i| i * n + j).collect());
        }
        Self::new(m * n, sets)
    }

    /// Number of cover sets k (the paper's memory quantity).
    pub fn k(&self) -> usize {
        self.sets.len()
    }

    /// Dimension d of the covered index space.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Σ|S_r| — per-step time complexity of the abstract algorithm.
    pub fn work(&self) -> usize {
        self.sets.iter().map(Vec::len).sum()
    }
}

/// Abstract SM3-I (Algorithm SM3-I, verbatim).
pub struct CoverSm3I {
    /// the cover the accumulators live on
    pub cover: Cover,
    /// μ_t(r), one per cover set — the O(k) memory of the paper
    pub mu: Vec<f32>,
}

impl CoverSm3I {
    /// Fresh optimizer state (μ = 0) over `cover`.
    pub fn new(cover: Cover) -> Self {
        let k = cover.k();
        Self { cover, mu: vec![0.0; k] }
    }

    /// One update step; returns the ν_t vector used (for tests).
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32) -> Vec<f32> {
        let gd = g.data();
        // μ_t(r) ← μ_{t-1}(r) + max_{j∈S_r} g_t²(j)
        for (r, set) in self.cover.sets.iter().enumerate() {
            let mx = set.iter().map(|&j| gd[j] * gd[j]).fold(0.0f32, f32::max);
            self.mu[r] += mx;
        }
        // ν_t(i) ← min_{r: S_r∋i} μ_t(r);  w ← w − η g/√ν
        let wd = w.data_mut();
        let mut nu = vec![0.0f32; wd.len()];
        for i in 0..wd.len() {
            let v = self.cover.covering[i]
                .iter()
                .map(|&r| self.mu[r])
                .fold(f32::INFINITY, f32::min);
            nu[i] = v;
            wd[i] -= lr * gd[i] * safe_rsqrt(v);
        }
        nu
    }
}

/// Abstract SM3-II (Algorithm SM3-II, verbatim).
pub struct CoverSm3II {
    /// the cover the accumulators live on
    pub cover: Cover,
    /// μ'_t(r)
    pub mu: Vec<f32>,
}

impl CoverSm3II {
    /// Fresh optimizer state (μ' = 0) over `cover`.
    pub fn new(cover: Cover) -> Self {
        let k = cover.k();
        Self { cover, mu: vec![0.0; k] }
    }

    /// One update step; returns ν'_t (for tests).
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32) -> Vec<f32> {
        let gd = g.data();
        let wd = w.data_mut();
        let mut new_mu = vec![0.0f32; self.cover.k()];
        let mut nu = vec![0.0f32; wd.len()];
        for i in 0..wd.len() {
            // ν'_t(i) ← min_{r∋i} μ'_{t-1}(r) + g_t²(i)
            let mn = self.cover.covering[i]
                .iter()
                .map(|&r| self.mu[r])
                .fold(f32::INFINITY, f32::min);
            let v = mn + gd[i] * gd[i];
            nu[i] = v;
            wd[i] -= lr * gd[i] * safe_rsqrt(v);
            // μ'_t(r) ← max(μ'_t(r), ν'_t(i)) for all r ∋ i
            for &r in &self.cover.covering[i] {
                if v > new_mu[r] {
                    new_mu[r] = v;
                }
            }
        }
        self.mu = new_mu;
        nu
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn grads(seed: u64, d: usize, t: usize) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        (0..t).map(|_| Tensor::randn(&[d], 1.0, &mut rng)).collect()
    }

    /// Diag cover ⇒ ν_t(i) = Σ g²(i) exactly (both variants) == Adagrad.
    #[test]
    fn diag_cover_is_adagrad() {
        let d = 12;
        let gs = grads(0, d, 8);
        let mut s1 = CoverSm3I::new(Cover::diag(d));
        let mut s2 = CoverSm3II::new(Cover::diag(d));
        let mut w1 = Tensor::zeros(&[d]);
        let mut w2 = Tensor::zeros(&[d]);
        let mut gsq = vec![0.0f32; d];
        for g in &gs {
            for (a, &gv) in gsq.iter_mut().zip(g.data()) {
                *a += gv * gv;
            }
            let nu1 = s1.step(&mut w1, g, 0.1);
            let nu2 = s2.step(&mut w2, g, 0.1);
            for i in 0..d {
                assert!((nu1[i] - gsq[i]).abs() < 1e-4);
                assert!((nu2[i] - gsq[i]).abs() < 1e-4);
            }
        }
        assert_eq!(w1, w2);
    }

    /// Claim 2 on an arbitrary overlapping cover.
    #[test]
    fn claim2_overlapping_cover() {
        let d = 10;
        let cover = Cover::new(d, vec![
            vec![0, 1, 2, 3],
            vec![2, 3, 4, 5, 6],
            vec![5, 6, 7, 8, 9],
            vec![0, 9],
        ]);
        let gs = grads(1, d, 12);
        let mut alg = CoverSm3I::new(cover);
        let mut w = Tensor::zeros(&[d]);
        let mut gsq = vec![0.0f64; d];
        let mut prev_nu = vec![0.0f32; d];
        for g in &gs {
            for (a, &gv) in gsq.iter_mut().zip(g.data()) {
                *a += (gv as f64) * (gv as f64);
            }
            let nu = alg.step(&mut w, g, 0.1);
            for i in 0..d {
                assert!(nu[i] as f64 + 1e-3 >= gsq[i], "lower bound");
                assert!(nu[i] + 1e-6 >= prev_nu[i], "monotone");
            }
            prev_nu = nu;
        }
    }

    /// Proposition 3 on an arbitrary cover: Σg² ≤ ν' ≤ ν.
    #[test]
    fn prop3_sandwich() {
        let d = 9;
        let cover = Cover::new(d, vec![
            vec![0, 1, 2],
            vec![3, 4, 5],
            vec![6, 7, 8],
            vec![0, 3, 6],
            vec![1, 4, 7],
            vec![2, 5, 8],
        ]); // 3x3 rows+cols
        let gs = grads(2, d, 10);
        let mut a1 = CoverSm3I::new(cover.clone());
        let mut a2 = CoverSm3II::new(cover);
        let mut w1 = Tensor::zeros(&[d]);
        let mut w2 = Tensor::zeros(&[d]);
        let mut gsq = vec![0.0f64; d];
        for g in &gs {
            for (a, &gv) in gsq.iter_mut().zip(g.data()) {
                *a += (gv as f64) * (gv as f64);
            }
            let nu = a1.step(&mut w1, g, 0.1);
            let nup = a2.step(&mut w2, g, 0.1);
            for i in 0..d {
                assert!(gsq[i] <= nup[i] as f64 + 1e-3);
                assert!(nup[i] <= nu[i] + 1e-5);
            }
        }
    }

    /// The production matrix fast path equals the abstract algorithm on
    /// the rows+cols cover (differential test), for both variants.
    #[test]
    fn fast_path_matches_abstract_rows_cols() {
        use crate::optim::{Optimizer, ParamSpec, Sm3, Sm3Variant};
        let (m, n) = (5, 7);
        for variant in [Sm3Variant::I, Sm3Variant::II] {
            let specs = vec![ParamSpec::new("w", &[m, n])];
            // beta1=0 so that momentum does not enter: abstract alg has none
            let mut fast = Sm3::new(&specs, variant, 0.0);
            let mut rng = Rng::new(3);
            let w0 = Tensor::randn(&[m, n], 0.5, &mut rng);
            let mut p_fast = vec![w0.clone()];
            let mut w_abs = w0.reshape(&[m * n]);
            let cover = Cover::rows_cols(m, n);
            let mut abs_i = CoverSm3I::new(cover.clone());
            let mut abs_ii = CoverSm3II::new(cover);
            for _ in 0..6 {
                let g = Tensor::randn(&[m, n], 1.0, &mut rng);
                fast.step(&mut p_fast, std::slice::from_ref(&g), 0.1);
                let gflat = g.clone().reshape(&[m * n]);
                match variant {
                    Sm3Variant::I => abs_i.step(&mut w_abs, &gflat, 0.1),
                    Sm3Variant::II => abs_ii.step(&mut w_abs, &gflat, 0.1),
                };
                for (a, b) in p_fast[0].data().iter().zip(w_abs.data()) {
                    assert!((a - b).abs() < 1e-5,
                            "{variant:?}: fast {a} vs abstract {b}");
                }
            }
        }
    }

    /// Memory: the abstract algorithm stores k floats, k = m+n for the
    /// rows+cols cover — the paper's headline claim in miniature.
    #[test]
    fn memory_is_k_not_d() {
        let cover = Cover::rows_cols(100, 200);
        let alg = CoverSm3II::new(cover);
        assert_eq!(alg.mu.len(), 300);
        assert_eq!(alg.cover.d(), 20_000);
    }

    #[test]
    #[should_panic]
    fn uncovered_index_panics() {
        Cover::new(3, vec![vec![0, 1]]);
    }
}
