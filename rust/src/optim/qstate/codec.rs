//! Scalar and block codecs for compressed optimizer-state storage.
//!
//! Two encodings below f32 (DESIGN.md §10):
//!
//! * **bf16** — round-to-nearest-even truncation of the f32 mantissa to
//!   7 bits (the classic carry trick). 2 bytes/scalar.
//! * **q8** — block-wise 8-bit: per [`Q8_BLOCK`]-element block one f32
//!   scale field holding the block's max |v| (`amax`) plus one u8 code per
//!   element. Codes are symmetric around [`Q8_ZERO_CODE`]:
//!   `byte = clamp(rne(v / (amax/127)), -127, 127) + 127`. ~1.06
//!   bytes/scalar amortized.
//!
//! Both codecs are deterministic pure functions of the input block, so
//! quantized state is bitwise reproducible at any `step_threads` setting
//! (blocks live inside one leaf's slot vector and shards are whole
//! leaves — a block can never straddle a shard boundary).
//!
//! **Idempotence contract** (relied on by checkpoint round-trips): for
//! both codecs, `encode(decode(e)) == e` bit-for-bit. For q8 this is why
//! the scale field stores `amax` rather than `amax/127`: codes ±127
//! decode to ±amax *exactly*, so a re-encode recovers the identical
//! scale field, and every interior code `q` decodes to `s·q` whose
//! re-quantization `rne((s·q)/s)` is `q` again (the two roundings move
//! the quotient by ≤ 2⁻²²·127, far inside the rounding bucket). Blocks
//! whose `amax/127` underflows to 0.0 are stored as all-zero blocks
//! (scale field 0.0) to keep the contract for subnormal inputs.
//!
//! Non-finite state values are a bug upstream (see `safe_rsqrt`); the
//! encoder debug-asserts on them, mirroring the optimizer bank's
//! convention. Release builds stay defined and NaN-free: a block whose
//! amax is infinite saturates (±inf → ±f32::MAX, finite → 0, still
//! idempotent), and a stray NaN codes to 0.

/// Elements per q8 block (one f32 scale per block).
pub const Q8_BLOCK: usize = 64;

/// The u8 code representing 0.0 (code space is `[0, 254]`, symmetric).
pub const Q8_ZERO_CODE: u8 = 127;

/// Round half-way cases to the nearest even integer (ties-to-even), the
/// IEEE default rounding. Implemented manually: `f32::round` is
/// ties-away-from-zero and `round_ties_even` is newer than our MSRV.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        // a tie implies |x| < 2^23, so the cast is exact
        f
    } else {
        f + 1.0
    }
}

/// f32 → bf16 with round-to-nearest-even (carry trick). NaN payloads are
/// quieted and truncated, never turned into infinities.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + round_bit) >> 16) as u16
}

/// bf16 → f32 (exact: every bf16 value is an f32 value).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Number of q8 blocks (scale fields) covering `len` elements.
/// Overflow-free on purpose: the checkpoint loader calls this with
/// attacker-controlled lengths before any allocation happens.
#[inline]
pub fn q8_blocks(len: usize) -> usize {
    len / Q8_BLOCK + usize::from(len % Q8_BLOCK != 0)
}

/// Quantize `vals` block-wise into pre-sized slices: one f32 amax per
/// block into `scales`, one u8 per element into `codes`. This is the
/// primitive the tile cursor re-encodes chunks with: because blocks are
/// independent, encoding a block-aligned sub-range writes exactly the
/// bytes a whole-slot encode would put there.
pub fn q8_encode_slice(vals: &[f32], scales: &mut [f32], codes: &mut [u8]) {
    debug_assert_eq!(scales.len(), q8_blocks(vals.len()));
    debug_assert_eq!(codes.len(), vals.len());
    for (bi, block) in vals.chunks(Q8_BLOCK).enumerate() {
        let cb = &mut codes[bi * Q8_BLOCK..bi * Q8_BLOCK + block.len()];
        let mut amax = 0.0f32;
        for &v in block {
            debug_assert!(v.is_finite(),
                          "non-finite optimizer-state value reached the q8 \
                           encoder (diverged accumulator?)");
            let a = v.abs();
            if a > amax {
                amax = a;
            }
        }
        if amax.is_infinite() {
            // Diverged accumulator (g² overflowed upstream). Debug builds
            // assert above; release saturates with defined, NaN-free
            // semantics: infinities code to ±127 and decode to ±f32::MAX
            // (the stored scale), finite values decode to 0. Re-encoding
            // the decoded block takes the normal path with amax = MAX and
            // reproduces these exact bytes, so idempotence still holds.
            scales[bi] = f32::MAX;
            for (c, &v) in cb.iter_mut().zip(block) {
                *c = if v == f32::INFINITY {
                    254
                } else if v == f32::NEG_INFINITY {
                    0
                } else {
                    Q8_ZERO_CODE
                };
            }
            continue;
        }
        let scale = amax / 127.0;
        if scale == 0.0 {
            // all-zero block, or amax so subnormal the step underflows:
            // store a canonical zero block (keeps encode∘decode == id)
            scales[bi] = 0.0;
            for c in cb.iter_mut() {
                *c = Q8_ZERO_CODE;
            }
            continue;
        }
        scales[bi] = amax;
        for (c, &v) in cb.iter_mut().zip(block) {
            let q = (round_ties_even(v / scale) as i32).clamp(-127, 127);
            *c = (q + 127) as u8;
        }
    }
}

/// Quantize `vals` block-wise into `scales` (one f32 amax per block) and
/// `codes` (one u8 per element). Output vectors are resized to fit (no
/// reallocation once capacity is warm — the steady-state step path).
pub fn q8_encode_into(vals: &[f32], scales: &mut Vec<f32>, codes: &mut Vec<u8>) {
    // resize only (no clear): the encoder overwrites every element
    scales.resize(q8_blocks(vals.len()), 0.0);
    codes.resize(vals.len(), 0);
    q8_encode_slice(vals, scales, codes);
}

/// Dequantize q8 blocks into a pre-sized slice (`out.len()` must equal
/// `codes.len()`). Codes ±127 decode to ±amax exactly — see the
/// idempotence contract in the module docs. Like [`q8_encode_slice`],
/// block independence makes a block-aligned sub-range decode identical
/// to the same positions of a whole-slot decode.
pub fn q8_decode_slice(scales: &[f32], codes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(scales.len(), q8_blocks(codes.len()));
    debug_assert_eq!(out.len(), codes.len());
    for (b, block) in codes.chunks(Q8_BLOCK).enumerate() {
        let ob = &mut out[b * Q8_BLOCK..b * Q8_BLOCK + block.len()];
        let amax = scales[b];
        let scale = amax / 127.0;
        for (o, &c) in ob.iter_mut().zip(block) {
            let q = c as i32 - 127;
            *o = match q {
                127 => amax,
                -127 => -amax,
                _ => scale * q as f32,
            };
        }
    }
}

/// Dequantize q8 blocks into `out` (resized to fit; no reallocation once
/// capacity is warm).
pub fn q8_decode_into(scales: &[f32], codes: &[u8], out: &mut Vec<f32>) {
    // resize only (no clear): the decoder overwrites every element
    out.resize(codes.len(), 0.0);
    q8_decode_slice(scales, codes, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, gen};

    #[test]
    fn round_ties_even_matches_ieee() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.5), 4.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(-2.5), -2.0);
        assert_eq!(round_ties_even(0.49), 0.0);
        assert_eq!(round_ties_even(0.51), 1.0);
        assert_eq!(round_ties_even(-126.5), -126.0);
        assert_eq!(round_ties_even(126.5), 126.0);
    }

    #[test]
    fn bf16_basics() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, f32::INFINITY,
                  f32::NEG_INFINITY] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)).to_bits(), x.to_bits(),
                       "{x} must be bf16-exact");
        }
        // 1 + 2^-8 is not representable: rounds to 1.0 (ties-to-even)
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 1.0 / 256.0)), 1.0);
        // 1 + 3·2^-8 ties between 1 + 2^-7 (odd mantissa) and 1 + 2^-6
        // (even mantissa): ties-to-even picks the latter
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 3.0 / 256.0)),
                   1.0 + 1.0 / 64.0);
        // NaN stays NaN (not an infinity)
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // beyond bf16-max rounds to infinity
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
    }

    /// Property: bf16 round-trip error is within half an ulp
    /// (relative 2^-8) and the codec is idempotent.
    #[test]
    fn prop_bf16_roundtrip_error_bound() {
        forall("bf16 round-trip", |rng| {
            let exp = rng.range(-30, 30) as f32;
            rng.normal_f32(0.0, 1.0) * 10f32.powf(exp)
        }, |&x| {
            let b = f32_to_bf16(x);
            let y = bf16_to_f32(b);
            if x.abs() >= f32::MIN_POSITIVE && y.is_finite() {
                let rel = (x - y).abs() / x.abs();
                if rel > 1.0 / 256.0 {
                    return Err(format!("rel err {rel} for {x} -> {y}"));
                }
            }
            if f32_to_bf16(y) != b {
                return Err(format!("not idempotent at {x}"));
            }
            Ok(())
        });
    }

    /// Property (ISSUE satellite): per block, quantize→dequantize error is
    /// bounded by half a step, `|v - v̂| ≤ (amax/127)/2` (+ f32 slack).
    #[test]
    fn prop_q8_roundtrip_error_bound_per_block() {
        forall("q8 per-block error bound", |rng| {
            let n = 1 + rng.index(200);
            let exp = rng.range(-8, 8) as f32;
            gen::grad_vec(rng, n, 10f32.powf(exp))
        }, |vals| {
            let (mut scales, mut codes) = (Vec::new(), Vec::new());
            q8_encode_into(vals, &mut scales, &mut codes);
            let mut dec = Vec::new();
            q8_decode_into(&scales, &codes, &mut dec);
            if dec.len() != vals.len() {
                return Err("length mismatch".into());
            }
            for (i, (&v, &d)) in vals.iter().zip(&dec).enumerate() {
                let step = scales[i / Q8_BLOCK] / 127.0;
                let bound = step * 0.5001 + 1e-30;
                if (v - d).abs() > bound {
                    return Err(format!(
                        "elem {i}: |{v} - {d}| > {bound} (step {step})"));
                }
            }
            Ok(())
        });
    }

    /// Property: encode∘decode is the identity on codec outputs — the
    /// contract checkpoint round-trips rely on.
    #[test]
    fn prop_q8_requantization_is_bitwise_idempotent() {
        forall("q8 idempotence", |rng| {
            let n = 1 + rng.index(200);
            let exp = rng.range(-10, 10) as f32;
            gen::grad_vec(rng, n, 10f32.powf(exp))
        }, |vals| {
            let (mut s1, mut c1) = (Vec::new(), Vec::new());
            q8_encode_into(vals, &mut s1, &mut c1);
            let mut dec = Vec::new();
            q8_decode_into(&s1, &c1, &mut dec);
            let (mut s2, mut c2) = (Vec::new(), Vec::new());
            q8_encode_into(&dec, &mut s2, &mut c2);
            if c1 != c2 {
                return Err("codes changed on re-encode".into());
            }
            for (a, b) in s1.iter().zip(&s2) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("scale changed: {a} -> {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn q8_zero_and_subnormal_blocks() {
        let (mut s, mut c) = (Vec::new(), Vec::new());
        q8_encode_into(&[0.0; 5], &mut s, &mut c);
        assert_eq!(s, vec![0.0]);
        assert_eq!(c, vec![Q8_ZERO_CODE; 5]);
        // amax/127 underflows to zero → canonical zero block
        let tiny = f32::from_bits(1); // smallest positive subnormal
        q8_encode_into(&[tiny, -tiny], &mut s, &mut c);
        assert_eq!(s, vec![0.0]);
        assert_eq!(c, vec![Q8_ZERO_CODE; 2]);
        let mut d = Vec::new();
        q8_decode_into(&s, &c, &mut d);
        assert_eq!(d, vec![0.0, 0.0]);
    }

    /// Debug builds surface non-finite state at the encoder, like
    /// `safe_rsqrt` surfaces NaN accumulators.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite")]
    fn q8_nonfinite_asserts_in_debug() {
        let (mut s, mut c) = (Vec::new(), Vec::new());
        q8_encode_into(&[1.0, f32::INFINITY], &mut s, &mut c);
    }

    /// Release builds only (debug asserts above): an inf-poisoned block
    /// saturates to ±f32::MAX / 0 with no NaNs, and stays idempotent.
    #[cfg(not(debug_assertions))]
    #[test]
    fn q8_infinite_blocks_saturate_without_nan() {
        let vals = [f32::INFINITY, f32::NEG_INFINITY, 3.0, 0.0];
        let (mut s, mut c) = (Vec::new(), Vec::new());
        q8_encode_into(&vals, &mut s, &mut c);
        assert_eq!(s, vec![f32::MAX]);
        let mut d = Vec::new();
        q8_decode_into(&s, &c, &mut d);
        assert_eq!(d, vec![f32::MAX, -f32::MAX, 0.0, 0.0]);
        // idempotence survives the degenerate path
        let (mut s2, mut c2) = (Vec::new(), Vec::new());
        q8_encode_into(&d, &mut s2, &mut c2);
        assert_eq!(s2[0].to_bits(), s[0].to_bits());
        assert_eq!(c2, c);
    }

    #[test]
    fn q8_extremes_decode_exactly() {
        let vals = [3.25f32, -3.25, 0.0, 1.625];
        let (mut s, mut c) = (Vec::new(), Vec::new());
        q8_encode_into(&vals, &mut s, &mut c);
        assert_eq!(s, vec![3.25]);
        let mut d = Vec::new();
        q8_decode_into(&s, &c, &mut d);
        // the max-magnitude elements decode bit-exactly
        assert_eq!(d[0], 3.25);
        assert_eq!(d[1], -3.25);
        assert_eq!(d[2], 0.0);
    }

    /// Property (ISSUE 3 tentpole): the tile-cursor contract — encoding
    /// and decoding block-aligned sub-ranges in any chunking produces
    /// bit-identical bytes to one whole-slot pass. Chunk sizes are
    /// multiples of [`Q8_BLOCK`]; lengths are deliberately odd.
    #[test]
    fn prop_q8_block_aligned_chunks_match_whole_slot() {
        forall("q8 chunk locality", |rng| {
            let n = 1 + rng.index(300);
            let chunk = Q8_BLOCK * (1 + rng.index(3));
            (gen::grad_vec(rng, n, 1.0), chunk)
        }, |(vals, chunk)| {
            let n = vals.len();
            let (mut s_whole, mut c_whole) = (Vec::new(), Vec::new());
            q8_encode_into(vals, &mut s_whole, &mut c_whole);
            // chunked encode into pre-sized buffers
            let mut s_chunk = vec![0.0f32; q8_blocks(n)];
            let mut c_chunk = vec![0u8; n];
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                let (b0, b1) = (lo / Q8_BLOCK, q8_blocks(hi));
                q8_encode_slice(&vals[lo..hi], &mut s_chunk[b0..b1],
                                &mut c_chunk[lo..hi]);
                lo = hi;
            }
            if c_chunk != c_whole {
                return Err("codes differ from whole-slot encode".into());
            }
            for (a, b) in s_chunk.iter().zip(&s_whole) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("scale differs: {a} vs {b}"));
                }
            }
            // chunked decode matches whole-slot decode
            let mut d_whole = Vec::new();
            q8_decode_into(&s_whole, &c_whole, &mut d_whole);
            let mut d_chunk = vec![0.0f32; n];
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                let (b0, b1) = (lo / Q8_BLOCK, q8_blocks(hi));
                q8_decode_slice(&s_whole[b0..b1], &c_whole[lo..hi],
                                &mut d_chunk[lo..hi]);
                lo = hi;
            }
            for (a, b) in d_chunk.iter().zip(&d_whole) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("decode differs: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    /// Satellite (ISSUE 6): `q8_encode_into` / `q8_decode_into` are thin
    /// resize+delegate wrappers over the slice versions — this gate pins
    /// the two paths bitwise together (including stale oversized / wrong-
    /// length output buffers, which the wrappers must resize) so a
    /// reimplemented block loop can never drift again.
    #[test]
    fn prop_into_wrappers_match_slice_versions_bitwise() {
        forall("q8 into == slice", |rng| {
            let n = 1 + rng.index(300);
            let exp = rng.range(-6, 6) as f32;
            // stale garbage length forces the resize path both ways
            (gen::grad_vec(rng, n, 10f32.powf(exp)), rng.index(400))
        }, |(vals, stale)| {
            let n = vals.len();
            let (mut s_into, mut c_into) =
                (vec![9.0f32; *stale], vec![9u8; *stale]);
            q8_encode_into(vals, &mut s_into, &mut c_into);
            let mut s_slice = vec![0.0f32; q8_blocks(n)];
            let mut c_slice = vec![0u8; n];
            q8_encode_slice(vals, &mut s_slice, &mut c_slice);
            if c_into != c_slice {
                return Err("encode codes drifted from slice path".into());
            }
            for (a, b) in s_into.iter().zip(&s_slice) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("encode scale drifted: {a} vs {b}"));
                }
            }
            let mut d_into = vec![9.0f32; *stale];
            q8_decode_into(&s_into, &c_into, &mut d_into);
            let mut d_slice = vec![0.0f32; n];
            q8_decode_slice(&s_into, &c_into, &mut d_slice);
            if d_into.len() != n {
                return Err("decode_into did not resize".into());
            }
            for (a, b) in d_into.iter().zip(&d_slice) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("decode drifted: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn q8_block_partitioning() {
        assert_eq!(q8_blocks(0), 0);
        assert_eq!(q8_blocks(1), 1);
        assert_eq!(q8_blocks(64), 1);
        assert_eq!(q8_blocks(65), 2);
        assert_eq!(q8_blocks(128), 2);
        let vals: Vec<f32> = (0..130).map(|i| i as f32).collect();
        let (mut s, mut c) = (Vec::new(), Vec::new());
        q8_encode_into(&vals, &mut s, &mut c);
        assert_eq!(s.len(), 3);
        assert_eq!(c.len(), 130);
        // per-block scales: blocks see different amax
        assert_eq!(s[0], 63.0);
        assert_eq!(s[1], 127.0);
        assert_eq!(s[2], 129.0);
    }
}
