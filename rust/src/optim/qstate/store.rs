//! The quantized slot store: dtype-tagged storage for optimizer state
//! vectors with dequantize-on-read / quantize-on-write semantics.
//!
//! A [`QSlot`] owns one state vector in its storage encoding; a
//! [`QuantizedSlots`] is the per-optimizer collection the bank's
//! optimizers allocate their accumulator and momentum slots from. The
//! update arithmetic never sees the encoding. Two access shapes exist:
//!
//! * **Whole-slot** ([`QSlot::read_into`] / [`QSlot::write`]) — dequantize
//!   the full vector into an f32 buffer, mutate, re-quantize. The
//!   checkpoint/introspection path, and the shape reduction-coupled
//!   optimizers (SM3 matrix/tensor covers, Adafactor) keep.
//! * **Tiled streaming** ([`QSlot::chunks_mut`]) — a [`ChunkCursor`]
//!   walks the slot in fixed tiles (any multiple of the q8 64-element
//!   block) and lends each tile as a [`TileMut`]: for f32 storage the
//!   tile borrows the backing `Vec<f32>` directly (zero copies — the
//!   memcpy the old whole-slot-only design paid on the hot path is
//!   gone); for bf16/q8 it decodes into a small caller-owned scratch
//!   and re-encodes into the backing bytes when the tile drops
//!   (commit-on-drop). Because tile boundaries sit on q8 block
//!   boundaries and both codecs are per-block pure functions, the
//!   streamed result is bitwise identical to a whole-slot pass —
//!   property-tested here and per optimizer in `crate::proptest`.
//!
//! `bench_optim`'s chunked-vs-whole-slot section measures what the
//! removed memcpys and the O(tile) working set buy next to the
//! sqrt/div-bound update arithmetic.

use super::codec;
use super::StateDtype;
use crate::optim::backend::Backend;
use crate::pool::{Pool, PoolBuf, Tag};

/// One state vector in its storage encoding.
pub struct QSlot {
    len: usize,
    data: SlotData,
    /// kernel backend the codec lanes dispatch through (bitwise
    /// identical across backends — DESIGN.md §13)
    backend: Backend,
}

/// Backing storage lives in pool leases tagged [`Tag::OptState`]
/// (legacy constructors hand out unpooled leases so pre-pool call
/// sites keep their exact behavior).
enum SlotData {
    F32(PoolBuf<f32>),
    Bf16(PoolBuf<u16>),
    Q8 { scales: PoolBuf<f32>, codes: PoolBuf<u8> },
}

impl QSlot {
    /// A zero-initialized slot of `len` scalars (unpooled storage; the
    /// trainer path allocates through [`QSlot::zeros_in`]).
    pub fn zeros(len: usize, dtype: StateDtype) -> Self {
        let data = match dtype {
            StateDtype::F32 => {
                SlotData::F32(PoolBuf::from_vec(Tag::OptState, vec![0.0; len]))
            }
            StateDtype::Bf16 => {
                SlotData::Bf16(PoolBuf::from_vec(Tag::OptState, vec![0; len]))
            }
            StateDtype::Q8 => SlotData::Q8 {
                scales: PoolBuf::from_vec(
                    Tag::OptState, vec![0.0; codec::q8_blocks(len)]),
                codes: PoolBuf::from_vec(
                    Tag::OptState, vec![codec::Q8_ZERO_CODE; len]),
            },
        };
        Self { len, data, backend: Backend::default() }
    }

    /// A zero-initialized slot whose storage is leased from `pool`
    /// under [`Tag::OptState`]. Bitwise identical to [`QSlot::zeros`]:
    /// pool leases arrive zero-filled, and the q8 code plane is re-set
    /// to the codec's zero code just as the fresh-vec path does.
    pub fn zeros_in(len: usize, dtype: StateDtype, pool: &Pool) -> Self {
        let data = match dtype {
            StateDtype::F32 => SlotData::F32(pool.take_f32(Tag::OptState, len)),
            StateDtype::Bf16 => {
                SlotData::Bf16(pool.take_u16(Tag::OptState, len))
            }
            StateDtype::Q8 => {
                let scales =
                    pool.take_f32(Tag::OptState, codec::q8_blocks(len));
                let mut codes = pool.take_u8(Tag::OptState, len);
                codes.fill(codec::Q8_ZERO_CODE);
                SlotData::Q8 { scales, codes }
            }
        };
        Self { len, data, backend: Backend::default() }
    }

    /// Route this slot's encode/decode lanes through `backend` (bitwise
    /// identical across backends; stores propagate this via
    /// [`QuantizedSlots::set_backend`]).
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// Quantize `vals` into a fresh slot.
    pub fn from_f32(dtype: StateDtype, vals: &[f32]) -> Self {
        let mut s = Self::zeros(vals.len(), dtype);
        s.write(vals);
        s
    }

    /// Storage precision of this slot.
    pub fn dtype(&self) -> StateDtype {
        match &self.data {
            SlotData::F32(_) => StateDtype::F32,
            SlotData::Bf16(_) => StateDtype::Bf16,
            SlotData::Q8 { .. } => StateDtype::Q8,
        }
    }

    /// Logical length in scalars.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the slot zero-length?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Dequantize into `out` (cleared first; `out.len()` becomes
    /// `self.len()`).
    pub fn read_into(&self, out: &mut Vec<f32>) {
        match &self.data {
            SlotData::F32(v) => {
                out.clear();
                out.extend_from_slice(v);
            }
            SlotData::Bf16(v) => {
                // resize only (no clear): the decoder overwrites every
                // element
                out.resize(v.len(), 0.0);
                self.backend.imp().bf16_decode(v, out);
            }
            SlotData::Q8 { scales, codes } => {
                out.resize(codes.len(), 0.0);
                self.backend.imp().q8_decode(scales, codes, out);
            }
        }
    }

    /// Dequantize into a fresh vector.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.read_into(&mut out);
        out
    }

    /// Quantize `vals` into this slot (length must match).
    pub fn write(&mut self, vals: &[f32]) {
        assert_eq!(vals.len(), self.len,
                   "slot length mismatch: wrote {} into a {}-scalar slot",
                   vals.len(), self.len);
        match &mut self.data {
            SlotData::F32(v) => v.copy_from_slice(vals),
            SlotData::Bf16(v) => {
                self.backend.imp().bf16_encode(vals, v);
            }
            SlotData::Q8 { scales, codes } => {
                // scales/codes were sized at construction and lengths
                // are asserted above, so the slice encode fits exactly
                self.backend.imp().q8_encode(vals, scales, codes);
            }
        }
    }

    /// Exact storage bytes of this slot (q8 includes the block scales).
    pub fn state_bytes(&self) -> usize {
        match &self.data {
            SlotData::F32(v) => v.len() * 4,
            SlotData::Bf16(v) => v.len() * 2,
            SlotData::Q8 { scales, codes } => scales.len() * 4 + codes.len(),
        }
    }

    /// Borrow the raw f32 backing storage (`None` for quantized slots).
    /// The zero-copy contract's observable: tiles from [`QSlot::chunks_mut`]
    /// of an f32 slot alias this storage directly.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.data {
            SlotData::F32(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Stream the slot as mutable f32 tiles of at most `tile` scalars
    /// (the last tile is the remainder). `tile` must be a positive
    /// multiple of [`codec::Q8_BLOCK`] so every tile starts on a q8
    /// block boundary — the invariant that makes per-tile re-encoding
    /// bitwise identical to a whole-slot pass. `scratch` is the decode
    /// buffer for bf16/q8 tiles, reused across tiles (and across calls:
    /// hand the same buffer back and steady-state streaming allocates
    /// nothing); f32 tiles never touch it.
    pub fn chunks_mut<'s>(&'s mut self, tile: usize,
                          scratch: &'s mut Vec<f32>) -> ChunkCursor<'s> {
        assert!(tile > 0 && tile % codec::Q8_BLOCK == 0,
                "tile size {} must be a positive multiple of the q8 block \
                 ({})", tile, codec::Q8_BLOCK);
        ChunkCursor { slot: self, scratch, tile, pos: 0 }
    }
}

/// A cursor streaming one [`QSlot`] as fixed-size mutable f32 tiles.
/// Obtain via [`QSlot::chunks_mut`]; drive with [`ChunkCursor::next_tile`]
/// (a lending iterator — each [`TileMut`] must drop before the next is
/// taken, which is what commits quantized tiles in order).
pub struct ChunkCursor<'s> {
    slot: &'s mut QSlot,
    scratch: &'s mut Vec<f32>,
    tile: usize,
    pos: usize,
}

impl ChunkCursor<'_> {
    /// The next tile, or `None` once the slot is exhausted.
    pub fn next_tile(&mut self) -> Option<TileMut<'_>> {
        let len = self.slot.len;
        if self.pos >= len {
            return None;
        }
        let start = self.pos;
        let n = self.tile.min(len - start);
        self.pos = start + n;
        let backend = self.slot.backend;
        Some(match &mut self.slot.data {
            SlotData::F32(v) => TileMut {
                offset: start,
                backend,
                buf: TileBuf::Lent(&mut v[start..start + n]),
            },
            SlotData::Bf16(v) => {
                let back = &mut v[start..start + n];
                // resize only (no clear): the decoder overwrites every
                // element
                self.scratch.resize(n, 0.0);
                backend.imp().bf16_decode(back, self.scratch);
                TileMut {
                    offset: start,
                    backend,
                    buf: TileBuf::Bf16 { scratch: &mut self.scratch[..n], back },
                }
            }
            SlotData::Q8 { scales, codes } => {
                // tiles start block-aligned, so the covering scale range
                // is exactly [start/B, blocks(start + n))
                let b0 = start / codec::Q8_BLOCK;
                let b1 = codec::q8_blocks(start + n);
                let scales = &mut scales[b0..b1];
                let codes = &mut codes[start..start + n];
                // resize only (no clear): the decoder overwrites every
                // element, so zero-filling would just double the writes
                self.scratch.resize(n, 0.0);
                backend.imp().q8_decode(scales, codes, self.scratch);
                TileMut {
                    offset: start,
                    backend,
                    buf: TileBuf::Q8 { scratch: &mut self.scratch[..n],
                                       scales, codes },
                }
            }
        })
    }
}

/// One mutable f32 tile of a slot. Dereferences to `[f32]`. For f32
/// storage this *is* the backing storage (zero-copy lend); for bf16/q8
/// it is the decoded scratch, re-encoded into the backing bytes when the
/// tile drops (commit-on-drop) — so mutations are durable exactly once,
/// with one deterministic quantization per tile.
pub struct TileMut<'a> {
    offset: usize,
    backend: Backend,
    buf: TileBuf<'a>,
}

enum TileBuf<'a> {
    Lent(&'a mut [f32]),
    Bf16 { scratch: &'a mut [f32], back: &'a mut [u16] },
    Q8 { scratch: &'a mut [f32], scales: &'a mut [f32], codes: &'a mut [u8] },
}

impl TileMut<'_> {
    /// Element offset of this tile within its slot.
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Does this tile lend the backing f32 storage directly (no copy)?
    pub fn is_lent(&self) -> bool {
        matches!(self.buf, TileBuf::Lent(_))
    }
}

impl std::ops::Deref for TileMut<'_> {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match &self.buf {
            TileBuf::Lent(v) => v,
            TileBuf::Bf16 { scratch, .. } | TileBuf::Q8 { scratch, .. } => {
                scratch
            }
        }
    }
}

impl std::ops::DerefMut for TileMut<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        match &mut self.buf {
            TileBuf::Lent(v) => v,
            TileBuf::Bf16 { scratch, .. } | TileBuf::Q8 { scratch, .. } => {
                scratch
            }
        }
    }
}

impl Drop for TileMut<'_> {
    fn drop(&mut self) {
        match &mut self.buf {
            TileBuf::Lent(_) => {} // mutations landed in place
            TileBuf::Bf16 { scratch, back } => {
                self.backend.imp().bf16_encode(scratch, back);
            }
            TileBuf::Q8 { scratch, scales, codes } => {
                self.backend.imp().q8_encode(scratch, scales, codes);
            }
        }
    }
}

/// A per-optimizer collection of [`QSlot`]s, all in one [`StateDtype`].
///
/// Optimizers allocate slots at construction ([`QuantizedSlots::add_zeros`]
/// returns a stable integer id) and step through read/modify/write.
pub struct QuantizedSlots {
    dtype: StateDtype,
    backend: Backend,
    slots: Vec<QSlot>,
    /// lease source for slot storage; `None` = legacy unpooled mode
    pool: Option<Pool>,
}

impl QuantizedSlots {
    /// An empty store whose future slots use `dtype` (unpooled storage;
    /// the trainer path constructs through [`QuantizedSlots::new_in`]).
    pub fn new(dtype: StateDtype) -> Self {
        Self { dtype, backend: Backend::default(), slots: Vec::new(),
               pool: None }
    }

    /// An empty store whose future slots lease their storage from
    /// `pool` under [`Tag::OptState`].
    pub fn new_in(dtype: StateDtype, pool: Pool) -> Self {
        Self { dtype, backend: Backend::default(), slots: Vec::new(),
               pool: Some(pool) }
    }

    /// Storage precision of every slot in the store.
    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Kernel backend the store's codec lanes dispatch through.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Route every slot's encode/decode lanes (current and future)
    /// through `backend`. Bitwise identical across backends — a pure
    /// performance knob, safe to flip on a live store.
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
        for s in &mut self.slots {
            s.set_backend(backend);
        }
    }

    /// Allocate a zero slot of `len` scalars; returns its id. Storage
    /// comes from the store's pool when one was attached at
    /// construction ([`QuantizedSlots::new_in`]).
    pub fn add_zeros(&mut self, len: usize) -> usize {
        let mut slot = match &self.pool {
            Some(p) => QSlot::zeros_in(len, self.dtype, p),
            None => QSlot::zeros(len, self.dtype),
        };
        slot.set_backend(self.backend);
        self.slots.push(slot);
        self.slots.len() - 1
    }

    /// Number of slots allocated.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Logical length of slot `id` in scalars.
    pub fn slot_len(&self, id: usize) -> usize {
        self.slots[id].len()
    }

    /// Dequantize slot `id` into `out` (cleared first).
    pub fn read_into(&self, id: usize, out: &mut Vec<f32>) {
        self.slots[id].read_into(out);
    }

    /// Dequantize slot `id` into a fresh vector.
    pub fn to_vec(&self, id: usize) -> Vec<f32> {
        self.slots[id].to_vec()
    }

    /// Quantize `vals` into slot `id` (length must match).
    pub fn write(&mut self, id: usize, vals: &[f32]) {
        self.slots[id].write(vals);
    }

    /// Mutable access to one slot (the tile-streaming entry point).
    pub fn slot_mut(&mut self, id: usize) -> &mut QSlot {
        &mut self.slots[id]
    }

    /// Disjoint mutable access to two distinct slots — lets the kernel
    /// layer stream e.g. an accumulator and its momentum in lockstep.
    pub fn slot_pair_mut(&mut self, a: usize, b: usize)
                         -> (&mut QSlot, &mut QSlot) {
        assert_ne!(a, b, "slot_pair_mut needs distinct slot ids");
        if a < b {
            let (lo, hi) = self.slots.split_at_mut(b);
            (&mut lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.slots.split_at_mut(a);
            (&mut hi[0], &mut lo[b])
        }
    }

    /// Total state scalars across all slots (the paper's memory quantity).
    pub fn state_floats(&self) -> usize {
        self.slots.iter().map(QSlot::len).sum()
    }

    /// Exact storage bytes across all slots.
    pub fn state_bytes(&self) -> usize {
        self.slots.iter().map(QSlot::state_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_read_back_as_zeros() {
        for dtype in StateDtype::ALL {
            let s = QSlot::zeros(100, dtype);
            assert_eq!(s.len(), 100);
            assert_eq!(s.dtype(), dtype);
            assert!(s.to_vec().iter().all(|&v| v == 0.0), "{dtype:?}");
        }
    }

    #[test]
    fn f32_slots_are_lossless() {
        let vals = [1.0e-20f32, -3.7, 0.0, 2.5e17, f32::MIN_POSITIVE];
        let s = QSlot::from_f32(StateDtype::F32, &vals);
        let got = s.to_vec();
        for (a, b) in vals.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn write_read_write_is_stable() {
        // second write of the dequantized values must not drift (the
        // codec idempotence contract, exercised through the store)
        let vals: Vec<f32> = (0..200).map(|i| (i as f32 - 100.0) * 0.37).collect();
        for dtype in StateDtype::ALL {
            let mut s = QSlot::from_f32(dtype, &vals);
            let once = s.to_vec();
            s.write(&once);
            let twice = s.to_vec();
            for (a, b) in once.iter().zip(&twice) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "slot length mismatch")]
    fn length_mismatch_panics() {
        let mut s = QSlot::zeros(4, StateDtype::Q8);
        s.write(&[1.0, 2.0]);
    }

    #[test]
    fn state_bytes_exact() {
        // 100 scalars: f32 400 B; bf16 200 B; q8 2 blocks·4 B + 100 B
        assert_eq!(QSlot::zeros(100, StateDtype::F32).state_bytes(), 400);
        assert_eq!(QSlot::zeros(100, StateDtype::Bf16).state_bytes(), 200);
        assert_eq!(QSlot::zeros(100, StateDtype::Q8).state_bytes(), 108);
        // exact block boundary
        assert_eq!(QSlot::zeros(64, StateDtype::Q8).state_bytes(), 68);
        assert_eq!(QSlot::zeros(0, StateDtype::Q8).state_bytes(), 0);
    }

    #[test]
    fn store_allocates_sequential_ids() {
        let mut st = QuantizedSlots::new(StateDtype::Q8);
        assert_eq!(st.add_zeros(10), 0);
        assert_eq!(st.add_zeros(64), 1);
        assert_eq!(st.slot_count(), 2);
        assert_eq!(st.slot_len(1), 64);
        assert_eq!(st.state_floats(), 74);
        assert_eq!(st.state_bytes(), (4 + 10) + (4 + 64));
        st.write(0, &[1.0; 10]);
        let mut buf = Vec::new();
        st.read_into(0, &mut buf);
        assert_eq!(buf.len(), 10);
        // 1.0 is the block max → decodes exactly
        assert!(buf.iter().all(|&v| v == 1.0));
    }

    /// Acceptance line (ISSUE 3): the f32 fast path performs zero slot
    /// copies — every tile aliases the backing storage directly and the
    /// scratch buffer is never touched.
    #[test]
    fn f32_tiles_lend_backing_storage_zero_copy() {
        let vals: Vec<f32> = (0..300).map(|i| i as f32 * 0.5).collect();
        let mut s = QSlot::from_f32(StateDtype::F32, &vals);
        let base = s.as_f32().unwrap().as_ptr() as usize;
        let mut scratch = Vec::new();
        let mut cur = s.chunks_mut(64, &mut scratch);
        let mut seen = 0;
        while let Some(tile) = cur.next_tile() {
            assert!(tile.is_lent());
            assert_eq!(tile.as_ptr() as usize, base + 4 * tile.offset(),
                       "tile at {} does not alias storage", tile.offset());
            seen += tile.len();
        }
        assert_eq!(seen, 300);
        assert_eq!(scratch.capacity(), 0, "f32 path must not touch scratch");
    }

    /// Tiled mutation == whole-slot mutation, bitwise, for every dtype
    /// and odd lengths (tiles of 64 and 128 against one full-slot pass).
    #[test]
    fn chunked_mutation_matches_whole_slot_bitwise() {
        let f = |i: usize, x: f32| x * 1.25 + (i % 7) as f32 * 0.125 - 0.5;
        for dtype in StateDtype::ALL {
            for len in [1usize, 63, 64, 65, 130, 257] {
                let vals: Vec<f32> =
                    (0..len).map(|i| (i as f32 - 40.0) * 0.37).collect();
                for tile in [64usize, 128] {
                    // whole-slot reference: read, mutate, write
                    let mut whole = QSlot::from_f32(dtype, &vals);
                    let mut buf = whole.to_vec();
                    for (i, x) in buf.iter_mut().enumerate() {
                        *x = f(i, *x);
                    }
                    whole.write(&buf);
                    // tiled: mutate through the cursor, commit on drop
                    let mut tiled = QSlot::from_f32(dtype, &vals);
                    let mut scratch = Vec::new();
                    let mut cur = tiled.chunks_mut(tile, &mut scratch);
                    while let Some(mut t) = cur.next_tile() {
                        let off = t.offset();
                        for (i, x) in t.iter_mut().enumerate() {
                            *x = f(off + i, *x);
                        }
                    }
                    let (a, b) = (whole.to_vec(), tiled.to_vec());
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "{dtype:?} len {len} tile {tile}: \
                                    {x} != {y}");
                    }
                    assert_eq!(whole.state_bytes(), tiled.state_bytes());
                }
            }
        }
    }

    /// Quantized tiles only become durable when they drop (commit-on-drop),
    /// and scratch capacity is bounded by one tile, not the slot.
    #[test]
    fn quantized_tiles_commit_on_drop() {
        let vals = [2.0f32; 200];
        let mut s = QSlot::from_f32(StateDtype::Q8, &vals);
        let mut scratch = Vec::new();
        {
            let mut cur = s.chunks_mut(64, &mut scratch);
            let mut t = cur.next_tile().unwrap();
            assert!(!t.is_lent());
            for x in t.iter_mut() {
                *x = 4.0;
            }
            drop(t); // first tile committed
            let t2 = cur.next_tile().unwrap();
            // second tile still sees the original encoding
            assert_eq!(t2[0], 2.0);
        }
        let got = s.to_vec();
        assert_eq!(got[0], 4.0); // amax element decodes exactly
        assert_eq!(got[63], 4.0);
        assert_eq!(got[64], 2.0);
        assert!(scratch.capacity() >= 64 && scratch.capacity() < 200,
                "scratch should hold one tile, got capacity {}",
                scratch.capacity());
    }

    #[test]
    #[should_panic(expected = "multiple of the q8 block")]
    fn misaligned_tile_size_panics() {
        let mut s = QSlot::zeros(128, StateDtype::Q8);
        let mut scratch = Vec::new();
        let _ = s.chunks_mut(96, &mut scratch);
    }

    #[test]
    fn slot_pair_mut_is_disjoint_either_order() {
        let mut st = QuantizedSlots::new(StateDtype::F32);
        let a = st.add_zeros(10);
        let b = st.add_zeros(20);
        let (sa, sb) = st.slot_pair_mut(a, b);
        assert_eq!((sa.len(), sb.len()), (10, 20));
        let (sb2, sa2) = st.slot_pair_mut(b, a);
        assert_eq!((sb2.len(), sa2.len()), (20, 10));
    }

    /// ISSUE 6: the backend knob changes no stored byte — writing and
    /// tile-mutating a slot through the simd lanes is bitwise identical
    /// to the scalar reference, at every dtype and off-grid lengths.
    #[test]
    fn backend_is_bitwise_invisible_in_storage() {
        let f = |i: usize, x: f32| x * 1.0625 + (i % 5) as f32 * 0.25 - 0.5;
        for dtype in StateDtype::ALL {
            for len in [1usize, 7, 63, 64, 65, 130, 257] {
                let vals: Vec<f32> =
                    (0..len).map(|i| (i as f32 - 40.0) * 0.37).collect();
                let mut sc = QSlot::from_f32(dtype, &vals);
                sc.set_backend(Backend::Scalar);
                let mut sv = QSlot::zeros(len, dtype);
                sv.set_backend(Backend::Simd);
                sv.write(&vals);
                for slot in [&mut sc, &mut sv] {
                    let mut scratch = Vec::new();
                    let mut cur = slot.chunks_mut(64, &mut scratch);
                    while let Some(mut t) = cur.next_tile() {
                        let off = t.offset();
                        for (i, x) in t.iter_mut().enumerate() {
                            *x = f(off + i, *x);
                        }
                    }
                }
                let (a, b) = (sc.to_vec(), sv.to_vec());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(),
                               "{dtype:?} len {len}: {x} != {y}");
                }
            }
        }
    }

    /// Pool contract (ISSUE 9): a pooled store's live `OptState`
    /// occupancy equals its exact `state_bytes()` at every dtype, and
    /// drops to zero when the store is torn down.
    #[test]
    fn pooled_store_occupancy_matches_state_bytes() {
        for dtype in StateDtype::ALL {
            let pool = Pool::new();
            let mut st = QuantizedSlots::new_in(dtype, pool.clone());
            for len in [100usize, 64, 0, 257] {
                st.add_zeros(len);
            }
            assert_eq!(pool.bytes_in_use_tag(Tag::OptState), st.state_bytes(),
                       "{dtype:?}");
            assert_eq!(pool.bytes_in_use(), st.state_bytes());
            drop(st);
            assert_eq!(pool.bytes_in_use(), 0, "{dtype:?}");
        }
    }

    /// Pooled slots are bitwise identical to unpooled ones — including
    /// the q8 zero-code plane, and including slots whose storage was
    /// recycled from a previous (dirty) lease.
    #[test]
    fn pooled_slots_match_unpooled_bitwise() {
        let vals: Vec<f32> = (0..200).map(|i| (i as f32 - 77.0) * 0.31).collect();
        for dtype in StateDtype::ALL {
            let pool = Pool::new();
            // dirty the shelves first so recycling is actually exercised
            {
                let mut junk = QSlot::zeros_in(200, dtype, &pool);
                junk.write(&vals);
            }
            let pooled_zero = QSlot::zeros_in(200, dtype, &pool);
            let plain_zero = QSlot::zeros(200, dtype);
            for (a, b) in pooled_zero.to_vec().iter().zip(plain_zero.to_vec()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} zeros");
            }
            let mut pooled = QSlot::zeros_in(200, dtype, &pool);
            pooled.write(&vals);
            let plain = QSlot::from_f32(dtype, &vals);
            for (a, b) in pooled.to_vec().iter().zip(plain.to_vec()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} written");
            }
            assert_eq!(pooled.state_bytes(), plain.state_bytes());
        }
    }

    #[test]
    fn q8_quantization_error_is_small_relative() {
        let vals: Vec<f32> = (1..=128).map(|i| i as f32).collect();
        let s = QSlot::from_f32(StateDtype::Q8, &vals);
        for (v, d) in vals.iter().zip(s.to_vec()) {
            // error ≤ half a step = amax/254 per block
            assert!((v - d).abs() <= 128.0 / 254.0 + 1e-6, "{v} vs {d}");
        }
    }
}
