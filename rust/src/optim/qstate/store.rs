//! The quantized slot store: dtype-tagged storage for optimizer state
//! vectors with dequantize-on-read / quantize-on-write semantics.
//!
//! A [`QSlot`] owns one state vector in its storage encoding; a
//! [`QuantizedSlots`] is the per-optimizer collection the bank's
//! optimizers allocate their accumulator and momentum slots from. The
//! update arithmetic never sees the encoding: every step reads a slot
//! into an f32 buffer, runs the exact f32 op sequence, and writes the
//! result back (one deterministic quantization per slot per step). With
//! [`StateDtype::F32`] read/write are plain copies, so the f32 path is
//! bit-identical to the pre-qstate `Vec<f32>` fields it replaced.
//!
//! Known tradeoff: the uniform read/modify/write shape costs the f32
//! path two sequential memcpys per slot per step that the old in-place
//! fields did not pay. A zero-copy fast path (lending `&mut [f32]` out
//! of `SlotData::F32`) would split every optimizer's update loop into
//! two code paths; per this repo's perf-pass convention that rewrite
//! should land only with `bench_optim` numbers showing the memcpy
//! matters next to the sqrt/div-bound update arithmetic — the qstate
//! section of that bench measures exactly this.

use super::codec;
use super::StateDtype;

/// One state vector in its storage encoding.
pub struct QSlot {
    len: usize,
    data: SlotData,
}

enum SlotData {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    Q8 { scales: Vec<f32>, codes: Vec<u8> },
}

impl QSlot {
    /// A zero-initialized slot of `len` scalars.
    pub fn zeros(len: usize, dtype: StateDtype) -> Self {
        let data = match dtype {
            StateDtype::F32 => SlotData::F32(vec![0.0; len]),
            StateDtype::Bf16 => SlotData::Bf16(vec![0; len]),
            StateDtype::Q8 => SlotData::Q8 {
                scales: vec![0.0; codec::q8_blocks(len)],
                codes: vec![codec::Q8_ZERO_CODE; len],
            },
        };
        Self { len, data }
    }

    /// Quantize `vals` into a fresh slot.
    pub fn from_f32(dtype: StateDtype, vals: &[f32]) -> Self {
        let mut s = Self::zeros(vals.len(), dtype);
        s.write(vals);
        s
    }

    pub fn dtype(&self) -> StateDtype {
        match &self.data {
            SlotData::F32(_) => StateDtype::F32,
            SlotData::Bf16(_) => StateDtype::Bf16,
            SlotData::Q8 { .. } => StateDtype::Q8,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Dequantize into `out` (cleared first; `out.len()` becomes
    /// `self.len()`).
    pub fn read_into(&self, out: &mut Vec<f32>) {
        match &self.data {
            SlotData::F32(v) => {
                out.clear();
                out.extend_from_slice(v);
            }
            SlotData::Bf16(v) => {
                out.clear();
                out.reserve(v.len());
                for &b in v {
                    out.push(codec::bf16_to_f32(b));
                }
            }
            SlotData::Q8 { scales, codes } => {
                codec::q8_decode_into(scales, codes, out);
            }
        }
    }

    /// Dequantize into a fresh vector.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.read_into(&mut out);
        out
    }

    /// Quantize `vals` into this slot (length must match).
    pub fn write(&mut self, vals: &[f32]) {
        assert_eq!(vals.len(), self.len,
                   "slot length mismatch: wrote {} into a {}-scalar slot",
                   vals.len(), self.len);
        match &mut self.data {
            SlotData::F32(v) => v.copy_from_slice(vals),
            SlotData::Bf16(v) => {
                for (b, &x) in v.iter_mut().zip(vals) {
                    *b = codec::f32_to_bf16(x);
                }
            }
            SlotData::Q8 { scales, codes } => {
                codec::q8_encode_into(vals, scales, codes);
            }
        }
    }

    /// Exact storage bytes of this slot (q8 includes the block scales).
    pub fn state_bytes(&self) -> usize {
        match &self.data {
            SlotData::F32(v) => v.len() * 4,
            SlotData::Bf16(v) => v.len() * 2,
            SlotData::Q8 { scales, codes } => scales.len() * 4 + codes.len(),
        }
    }
}

/// A per-optimizer collection of [`QSlot`]s, all in one [`StateDtype`].
///
/// Optimizers allocate slots at construction ([`QuantizedSlots::add_zeros`]
/// returns a stable integer id) and step through read/modify/write.
pub struct QuantizedSlots {
    dtype: StateDtype,
    slots: Vec<QSlot>,
}

impl QuantizedSlots {
    pub fn new(dtype: StateDtype) -> Self {
        Self { dtype, slots: Vec::new() }
    }

    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Allocate a zero slot of `len` scalars; returns its id.
    pub fn add_zeros(&mut self, len: usize) -> usize {
        self.slots.push(QSlot::zeros(len, self.dtype));
        self.slots.len() - 1
    }

    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    pub fn slot_len(&self, id: usize) -> usize {
        self.slots[id].len()
    }

    /// Dequantize slot `id` into `out` (cleared first).
    pub fn read_into(&self, id: usize, out: &mut Vec<f32>) {
        self.slots[id].read_into(out);
    }

    /// Dequantize slot `id` into a fresh vector.
    pub fn to_vec(&self, id: usize) -> Vec<f32> {
        self.slots[id].to_vec()
    }

    /// Quantize `vals` into slot `id` (length must match).
    pub fn write(&mut self, id: usize, vals: &[f32]) {
        self.slots[id].write(vals);
    }

    /// Total state scalars across all slots (the paper's memory quantity).
    pub fn state_floats(&self) -> usize {
        self.slots.iter().map(QSlot::len).sum()
    }

    /// Exact storage bytes across all slots.
    pub fn state_bytes(&self) -> usize {
        self.slots.iter().map(QSlot::state_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_read_back_as_zeros() {
        for dtype in StateDtype::ALL {
            let s = QSlot::zeros(100, dtype);
            assert_eq!(s.len(), 100);
            assert_eq!(s.dtype(), dtype);
            assert!(s.to_vec().iter().all(|&v| v == 0.0), "{dtype:?}");
        }
    }

    #[test]
    fn f32_slots_are_lossless() {
        let vals = [1.0e-20f32, -3.7, 0.0, 2.5e17, f32::MIN_POSITIVE];
        let s = QSlot::from_f32(StateDtype::F32, &vals);
        let got = s.to_vec();
        for (a, b) in vals.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn write_read_write_is_stable() {
        // second write of the dequantized values must not drift (the
        // codec idempotence contract, exercised through the store)
        let vals: Vec<f32> = (0..200).map(|i| (i as f32 - 100.0) * 0.37).collect();
        for dtype in StateDtype::ALL {
            let mut s = QSlot::from_f32(dtype, &vals);
            let once = s.to_vec();
            s.write(&once);
            let twice = s.to_vec();
            for (a, b) in once.iter().zip(&twice) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "slot length mismatch")]
    fn length_mismatch_panics() {
        let mut s = QSlot::zeros(4, StateDtype::Q8);
        s.write(&[1.0, 2.0]);
    }

    #[test]
    fn state_bytes_exact() {
        // 100 scalars: f32 400 B; bf16 200 B; q8 2 blocks·4 B + 100 B
        assert_eq!(QSlot::zeros(100, StateDtype::F32).state_bytes(), 400);
        assert_eq!(QSlot::zeros(100, StateDtype::Bf16).state_bytes(), 200);
        assert_eq!(QSlot::zeros(100, StateDtype::Q8).state_bytes(), 108);
        // exact block boundary
        assert_eq!(QSlot::zeros(64, StateDtype::Q8).state_bytes(), 68);
        assert_eq!(QSlot::zeros(0, StateDtype::Q8).state_bytes(), 0);
    }

    #[test]
    fn store_allocates_sequential_ids() {
        let mut st = QuantizedSlots::new(StateDtype::Q8);
        assert_eq!(st.add_zeros(10), 0);
        assert_eq!(st.add_zeros(64), 1);
        assert_eq!(st.slot_count(), 2);
        assert_eq!(st.slot_len(1), 64);
        assert_eq!(st.state_floats(), 74);
        assert_eq!(st.state_bytes(), (4 + 10) + (4 + 64));
        st.write(0, &[1.0; 10]);
        let mut buf = Vec::new();
        st.read_into(0, &mut buf);
        assert_eq!(buf.len(), 10);
        // 1.0 is the block max → decodes exactly
        assert!(buf.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn q8_quantization_error_is_small_relative() {
        let vals: Vec<f32> = (1..=128).map(|i| i as f32).collect();
        let s = QSlot::from_f32(StateDtype::Q8, &vals);
        for (v, d) in vals.iter().zip(s.to_vec()) {
            // error ≤ half a step = amax/254 per block
            assert!((v - d).abs() <= 128.0 / 254.0 + 1e-6, "{v} vs {d}");
        }
    }
}
