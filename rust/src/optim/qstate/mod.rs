//! Quantized optimizer-state storage (DESIGN.md §10).
//!
//! The paper shrinks second-moment state by changing the *statistics*
//! (row/col covers); this subsystem shrinks it further by changing the
//! *storage precision*: any registry optimizer can keep its slots in
//! f32, bf16, or block-wise 8-bit (`q8`) while the update arithmetic
//! itself stays bit-stable f32 — dequantize-on-read / quantize-on-write
//! for whole-slot access, or tile-streamed through [`store::ChunkCursor`]
//! on the step hot path (see [`store::QuantizedSlots`]). Extends the
//! memory accountant's
//! Tables 1–2 past the paper's OOM frontier (`memory::opt_state_bytes`)
//! and opens a storage-precision axis for the quality sweeps.
//!
//! Determinism contract: both codecs are pure per-block functions and a
//! block always lives inside one leaf's slot vector, while `ParallelStep`
//! shards whole leaves — so quantized state is bitwise identical between
//! serial and sharded stepping at any thread count (property-tested in
//! `crate::proptest`).

pub mod codec;
pub mod store;

pub use store::{ChunkCursor, QSlot, QuantizedSlots, TileMut};

use anyhow::{bail, Result};

/// Storage precision for optimizer-state slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateDtype {
    /// 4 bytes/scalar — lossless, the seed behavior.
    F32,
    /// 2 bytes/scalar — round-to-nearest-even truncated f32.
    Bf16,
    /// ~1.06 bytes/scalar — per-64-element block f32 scale + u8 codes.
    Q8,
}

impl StateDtype {
    /// Every storage precision, in decreasing-size order.
    pub const ALL: [StateDtype; 3] =
        [StateDtype::F32, StateDtype::Bf16, StateDtype::Q8];

    /// Parse a config/CLI name ("f32" | "bf16" | "q8").
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => StateDtype::F32,
            "bf16" => StateDtype::Bf16,
            "q8" => StateDtype::Q8,
            other => bail!("unknown state dtype {other:?} (f32|bf16|q8)"),
        })
    }

    /// Canonical name (inverse of [`StateDtype::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            StateDtype::F32 => "f32",
            StateDtype::Bf16 => "bf16",
            StateDtype::Q8 => "q8",
        }
    }

    /// Amortized storage bytes per state scalar (q8 spreads the per-block
    /// f32 scale over [`codec::Q8_BLOCK`] elements). The memory
    /// accountant's per-dtype columns use [`StateDtype::bytes_for`],
    /// which is exact about partial trailing blocks.
    pub fn bytes_per_slot(self) -> f64 {
        match self {
            StateDtype::F32 => 4.0,
            StateDtype::Bf16 => 2.0,
            StateDtype::Q8 => 1.0 + 4.0 / codec::Q8_BLOCK as f64,
        }
    }

    /// Exact storage bytes for one slot vector of `len` scalars.
    pub fn bytes_for(self, len: usize) -> usize {
        match self {
            StateDtype::F32 => len * 4,
            StateDtype::Bf16 => len * 2,
            StateDtype::Q8 => codec::q8_blocks(len) * 4 + len,
        }
    }

    /// The `SM3CKPT2` entry tag (see `checkpoint.rs`).
    pub fn tag(self) -> u8 {
        match self {
            StateDtype::F32 => 0,
            StateDtype::Bf16 => 1,
            StateDtype::Q8 => 2,
        }
    }

    /// Inverse of [`StateDtype::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => StateDtype::F32,
            1 => StateDtype::Bf16,
            2 => StateDtype::Q8,
            other => bail!("unknown state-dtype tag {other}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip() {
        for dtype in StateDtype::ALL {
            assert_eq!(StateDtype::parse(dtype.name()).unwrap(), dtype);
        }
        assert!(StateDtype::parse("fp16").is_err());
        assert!(StateDtype::parse("").is_err());
    }

    #[test]
    fn tag_roundtrip() {
        for dtype in StateDtype::ALL {
            assert_eq!(StateDtype::from_tag(dtype.tag()).unwrap(), dtype);
        }
        assert!(StateDtype::from_tag(3).is_err());
        assert!(StateDtype::from_tag(255).is_err());
    }

    #[test]
    fn q8_beats_the_35x_reduction_target() {
        // the acceptance line: ≥ 3.5× smaller than f32 per scalar
        let red = StateDtype::F32.bytes_per_slot()
            / StateDtype::Q8.bytes_per_slot();
        assert!(red >= 3.5, "q8 amortized reduction {red}");
        // and exact accounting agrees for block-aligned lengths
        assert_eq!(StateDtype::Q8.bytes_for(64 * 100), 4 * 100 + 6400);
        assert_eq!(StateDtype::Bf16.bytes_for(10), 20);
        assert_eq!(StateDtype::F32.bytes_for(10), 40);
    }
}
