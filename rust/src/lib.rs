//! # SM3 — Memory-Efficient Adaptive Optimization
//!
//! A production-style reproduction of *"Memory-Efficient Adaptive
//! Optimization"* (Anil, Gupta, Koren, Singer — NeurIPS 2019), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas update kernels for
//!   SM3-I/SM3-II and all baselines, tested against pure-jnp oracles.
//! * **Layer 2** (`python/compile/`) — pure-JAX models (transformer LM,
//!   seq2seq translation, BERT-style masked LM, convnet) with fused
//!   per-optimizer train steps, AOT-lowered once to HLO text.
//! * **Layer 3** (this crate) — the training framework: configuration,
//!   synthetic data pipelines, a data-parallel coordinator with simulated
//!   collectives, a pure-Rust optimizer bank mirroring the kernels, the
//!   memory accountant that reproduces the paper's Tables 1–2, metrics
//!   (BLEU, perplexity, accuracy), checkpointing, and the PJRT runtime
//!   that executes the AOT artifacts. Python never runs at training time.
//!   On the split path the optimizer is constructed through the typed,
//!   composable [`optim::OptimSpec`] builder (per-method
//!   hyperparameters, chainable update transforms — gradient clipping
//!   and decoupled weight decay via [`optim::transform`] — and
//!   per-parameter-group overrides); the update streams through tiled
//!   step kernels ([`optim::kernel`]: zero-copy at f32, O(tile) scratch
//!   at bf16/q8) and shards across host threads ([`optim::parallel`],
//!   with intra-leaf splitting of dominant element-wise leaves) with
//!   bitwise-identical results; optimizer state can be stored quantized
//!   ([`optim::qstate`]: f32, bf16, or block-wise 8-bit) while the
//!   update arithmetic stays f32. The data-parallel gradient exchange
//!   runs through the [`comms`] subsystem (DESIGN.md §12): a
//!   thread-parallel chunked ring all-reduce over persistent flat
//!   buffers whose wire payloads can be compressed to bf16 or
//!   block-wise 8-bit with per-rank error-feedback residuals —
//!   bitwise-deterministic at any `comm_threads`, with the simulated
//!   pod interconnect cost reported per step. Live measurement runs
//!   through the determinism-neutral [`telemetry`] subsystem
//!   (DESIGN.md §14): per-phase spans, wire-byte counters, and memory
//!   gauges recorded into thread-local cells, aggregated into a
//!   [`telemetry::Registry`], and exported as per-phase `StepRecord`
//!   columns, an optional JSONL event stream, and the benches'
//!   `BENCH_*.json` perf trajectory — bitwise-invisible to training
//!   whether enabled or disabled. On top of the same cells, the
//!   per-event trace timeline ([`telemetry::trace_event`], DESIGN.md
//!   §17) records every span and counter/gauge update into lock-free
//!   per-thread ring buffers drained cold-side into Chrome-trace JSON
//!   (`--trace-out`), and the [`health`] watchdogs turn per-step
//!   telemetry deltas (non-finite scans, loss windows, hop timings,
//!   pool occupancy) into a logged `RunHealth` verdict that can halt
//!   a run under `[train] health_action = abort`. Every steady-state
//!   buffer behind
//!   those subsystems — optimizer-state slots, kernel scratch, comm
//!   flat/wire/residual slabs, transport edge slots, checkpoint stitch
//!   buffers — is leased from the size-classed [`pool`] runtime
//!   (DESIGN.md §16), whose live per-tag occupancy the static
//!   [`memory`] accountant must equal at step boundaries (enforced in
//!   tests), making peak-memory claims measured facts rather than
//!   hand-maintained mirrors.
//!
//! See `DESIGN.md` for the experiment index (every paper table/figure →
//! bench target) and `EXPERIMENTS.md` for measured results. This offline
//! build stubs the PJRT bindings (DESIGN.md §9): everything except HLO
//! artifact *execution* builds, runs, and is tested without them.

#[cfg(test)]
mod alloc_count;
pub mod bench_util;
pub mod checkpoint;
pub mod cli;
pub mod collectives;
pub mod comms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod health;
pub mod json;
pub mod memory;
pub mod metrics;
pub mod optim;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod trace;

/// Token-id conventions shared with `python/compile/aot.py`.
pub mod vocab {
    /// Padding token.
    pub const PAD: i32 = 0;
    /// Beginning-of-sequence token.
    pub const BOS: i32 = 1;
    /// End-of-sequence token.
    pub const EOS: i32 = 2;
    /// Unknown token.
    pub const UNK: i32 = 3;
    /// First regular (content) token id.
    pub const FIRST: i32 = 4;
}
