//! The trainer: step loop, both execution paths, eval, BLEU decode.

use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;

use crate::comms::{CommEngine, CommOpts, TimingModel};
use crate::config::{ExecMode, TrainConfig};
use crate::data::{source_for_model, translation::trim_ref, BatchSource};
use crate::health::{HealthMonitor, RunHealth, StepObs};
use crate::json::Json;
use crate::metrics::{corpus_bleu, Ema};
use crate::optim::{schedule::Schedule, Optimizer, StateDtype};
use crate::pool::Pool;
use crate::runtime::manifest::ModelMeta;
use crate::runtime::{Artifact, HostValue, Runtime};
use crate::telemetry::{self, trace_event, Counter, Gauge, Probe};
use crate::tensor::Tensor;

/// One training-step record (the loss-curve CSV row). The per-phase
/// `*_ms` columns are measured by the telemetry subsystem (DESIGN.md
/// §14) and are 0.0 while telemetry is disabled — `comm_ms` stays the
/// *modeled* interconnect cost either way.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f64,
    pub loss_ema: f64,
    pub lr: f64,
    pub wall_ms: f64,
    /// modeled pod-interconnect cost of this step's gradient exchange:
    /// the full staged-pipeline figure (`BucketPlan::modeled_seconds` —
    /// staging + hops, with staging hidden behind in-flight hops under
    /// `comm_overlap`). With telemetry on, the underlying `TimingModel`
    /// is refit each step from measured hop/stage spans
    /// (`TimingModel::from_measured`); otherwise the TPU-v2 pod defaults
    /// apply. 0.0 single-worker and on the fused path.
    pub comm_ms: f64,
    /// measured forward+backward time (all workers, all grad-accum
    /// microbatches)
    pub grad_ms: f64,
    /// measured optimizer-update time (`Optimizer::step`)
    pub opt_ms: f64,
    /// measured comm pack + error-feedback staging time
    pub comm_pack_ms: f64,
    /// measured ring-hop time (reduce + finalize-encode + gather sweeps)
    pub comm_hop_ms: f64,
    /// measured comm unpack (scatter + mean-scale) time
    pub comm_unpack_ms: f64,
    /// measured checkpoint-I/O time attributable to this step
    pub ckpt_ms: f64,
}

/// One evaluation record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: u64,
    pub loss: f64,
    /// task metric: masked-LM / top-1 accuracy, or BLEU for translation
    pub metric: Option<f64>,
    /// secondary metric (top-5 accuracy)
    pub metric2: Option<f64>,
}

/// Full run output.
#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
}

impl RunHistory {
    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    /// First step at which the eval metric reached `target` (Fig. 3-right).
    pub fn steps_to_metric(&self, target: f64) -> Option<u64> {
        self.evals
            .iter()
            .find(|e| e.metric.unwrap_or(f64::NEG_INFINITY) >= target)
            .map(|e| e.step)
    }

    /// First step at which the held-out loss dropped to `target` — the
    /// steps-to-quality measure used when the accuracy target is not
    /// reachable at miniature scale (see EXPERIMENTS.md Fig. 3 notes).
    pub fn steps_to_loss(&self, target: f64) -> Option<u64> {
        self.evals
            .iter()
            .find(|e| e.loss <= target)
            .map(|e| e.step)
    }
}

enum Engine {
    Split {
        grad_art: Arc<Artifact>,
        params: Vec<Tensor>,
        opt: Box<dyn Optimizer>,
        /// the gradient exchange (comms subsystem, DESIGN.md §12):
        /// persistent ring buffers + wire codec + error feedback
        comms: CommEngine,
        /// the memory-pool runtime every steady-state buffer above
        /// leases from (DESIGN.md §16). `train.pool = false` swaps in
        /// [`Pool::disabled`] — same leases, no recycling — which is
        /// bitwise identical and keeps the occupancy ledger live.
        pool: Pool,
    },
    Fused {
        train_art: Arc<Artifact>,
        /// params ++ opt state, kept in artifact input order
        state: Vec<HostValue>,
        n_params: usize,
    },
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub meta: ModelMeta,
    runtime: Arc<Runtime>,
    engine: Engine,
    eval_art: Arc<Artifact>,
    decode_art: Option<Arc<Artifact>>,
    sources: Vec<Box<dyn BatchSource>>,
    /// out-of-band data stream for `compute_grads` trace probes — forked
    /// from the same seed at shard index `cfg.workers`, so probing never
    /// advances (or collides with) any training worker's stream
    probe_source: Box<dyn BatchSource>,
    schedule: Schedule,
    step: u64,
    ema: Ema,
    /// simulated interconnect cost of the most recent `train_step`
    last_comm_ms: f64,
    /// measured (bytes, seconds) hop samples feeding
    /// `TimingModel::from_measured` (telemetry runs only; capped)
    comm_hop_samples: Vec<(usize, f64)>,
    /// measured (bytes, seconds) stage samples (pack + error feedback)
    comm_stage_samples: Vec<(usize, f64)>,
    /// keeps the process-wide telemetry flag raised for this trainer's
    /// lifetime when `cfg.telemetry` is set (guards nest across
    /// concurrent trainers)
    _telemetry: Option<telemetry::Enabled>,
    /// keeps per-event trace recording on for this trainer's lifetime
    /// when `cfg.trace_out` is set (DESIGN.md §17)
    _tracing: Option<telemetry::TracingGuard>,
    /// the accumulated trace timeline, drained from the rings at each
    /// step boundary and written as Chrome-trace JSON at run end
    timeline: Option<telemetry::Timeline>,
    /// the run-health watchdogs, evaluated at every step boundary from
    /// the step's telemetry deltas (DESIGN.md §17)
    health: HealthMonitor,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let runtime = Arc::new(Runtime::new(cfg.artifacts_dir.clone())?);
        Self::with_runtime(cfg, runtime)
    }

    /// Share one PJRT runtime (and its executable cache) across trainers —
    /// the benches construct many trainers over the same artifacts.
    pub fn with_runtime(cfg: TrainConfig, runtime: Arc<Runtime>) -> Result<Self> {
        let meta = runtime.manifest.model(&cfg.model)?.clone();
        let schedule = super::schedule_for(&cfg, meta.d_model.max(1))
            .context("resolving the LR schedule")?;

        let params = load_init_params(&cfg.artifacts_dir, &meta)?;

        let engine = match cfg.exec {
            ExecMode::Split => {
                let grad_art = runtime
                    .load(&format!("{}_grad", cfg.model))
                    .context("loading grad artifact")?;
                let specs = meta.param_specs();
                // The composable construction path (optim::OptimSpec,
                // DESIGN.md §11): the config's typed hyperparameters,
                // state-storage options (state_dtype / step_chunk),
                // update transforms (clip_value → clip_norm →
                // weight_decay), param groups, and the sharding plan
                // (step_threads; intra-leaf splitting) all resolve here
                // against the model's parameter list. Results stay
                // bitwise identical at any thread count, tile size, and
                // dtype (optim::parallel / optim::transform).
                // every steady-state buffer below (optimizer slots and
                // scratch, comm staging/residuals/wire slabs, transport
                // edges) leases from this pool, so its live ledger IS
                // the run's steady-state footprint. `pool = false`
                // keeps the ledger but skips recycling.
                let pool =
                    if cfg.pool { Pool::new() } else { Pool::disabled() };
                let opt = cfg
                    .optim_spec()?
                    .pool(&pool)
                    .build(&specs)
                    .context("building the optimizer from [optim]")?;
                // the gradient exchange: buffers, residuals, the
                // bucketed ring schedule, the hop transport, and (when
                // comm_overlap is on) the dedicated hop-worker thread
                // are all sized/spawned once, here
                let mut comms = CommEngine::with_opts_in(
                    &specs, cfg.workers,
                    CommOpts {
                        dtype: cfg.comm_dtype,
                        chunk: cfg.comm_chunk,
                        threads: cfg.comm_threads,
                        buckets: cfg.comm_buckets,
                        overlap: cfg.comm_overlap,
                        transport: cfg.comm_transport,
                    },
                    &pool)
                    .context("building the comm engine from [train]")?;
                // the optimizer side gets its backend via optim_spec();
                // the wire side is set here so both halves of the split
                // engine run the same kernels
                comms.set_backend(cfg.kernel_backend);
                Engine::Split { grad_art, params, opt, comms, pool }
            }
            ExecMode::Fused => {
                let name = format!("{}_train_{}", cfg.model, cfg.optim.name);
                let train_art = runtime.load(&name).with_context(|| {
                    format!("loading fused artifact {name} \
                             (is this optimizer in FUSED_OPTS for the model?)")
                })?;
                let state = fused_initial_state(&train_art, params)?;
                let n_params = meta.params.len();
                Engine::Fused { train_art, state, n_params }
            }
        };

        let eval_art = runtime.load(&format!("{}_eval", cfg.model))?;
        let decode_art = if meta.kind == "mt" {
            Some(runtime.load(&format!("{}_decode", cfg.model))?)
        } else {
            None
        };

        let sources: Vec<Box<dyn BatchSource>> = (0..cfg.workers)
            .map(|w| source_for_model(&meta, cfg.seed, w, cfg.workers))
            .collect::<Result<_>>()?;
        // shard index cfg.workers is outside every training worker's
        // range, so the probe stream is independent of all of them
        let probe_source =
            source_for_model(&meta, cfg.seed, cfg.workers, cfg.workers + 1)?;

        let tele_guard = cfg.telemetry.then(telemetry::enable);
        let tracing_guard =
            cfg.trace_out.is_some().then(telemetry::enable_tracing);
        if tracing_guard.is_some() {
            // the step loop runs on this thread: name its trace lane
            trace_event::set_thread_label("coordinator");
        }
        let timeline =
            cfg.trace_out.is_some().then(telemetry::Timeline::default);
        let health = HealthMonitor::standard(cfg.health_action);

        Ok(Self {
            cfg,
            meta,
            runtime,
            engine,
            eval_art,
            decode_art,
            sources,
            probe_source,
            schedule,
            step: 0,
            ema: Ema::new(0.9),
            last_comm_ms: 0.0,
            comm_hop_samples: Vec::new(),
            comm_stage_samples: Vec::new(),
            _telemetry: tele_guard,
            _tracing: tracing_guard,
            timeline,
            health,
        })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Current host-side parameters (clones; split mode borrows, fused
    /// mode converts from the artifact state).
    pub fn params(&self) -> Vec<Tensor> {
        match &self.engine {
            Engine::Split { params, .. } => params.clone(),
            Engine::Fused { state, n_params, .. } => state[..*n_params]
                .iter()
                .map(|v| v.as_f32().expect("params are f32").clone())
                .collect(),
        }
    }

    /// Introspect the optimizer (split mode only).
    pub fn optimizer(&self) -> Option<&dyn Optimizer> {
        match &self.engine {
            Engine::Split { opt, .. } => Some(opt.as_ref()),
            Engine::Fused { .. } => None,
        }
    }

    /// Introspect the memory pool every steady-state buffer leases
    /// from (split mode only).
    pub fn pool(&self) -> Option<&Pool> {
        match &self.engine {
            Engine::Split { pool, .. } => Some(pool),
            Engine::Fused { .. } => None,
        }
    }

    /// Introspect the gradient-exchange engine (split mode only).
    pub fn comms(&self) -> Option<&CommEngine> {
        match &self.engine {
            Engine::Split { comms, .. } => Some(comms),
            Engine::Fused { .. } => None,
        }
    }

    /// Restore the error-feedback residuals a compressed-comm checkpoint
    /// carries (`comm/residual/<rank>` entries, in rank order) so a
    /// resumed run continues bit-identically to the uninterrupted one.
    pub fn load_comm_residuals(&mut self, state: Vec<Tensor>) -> Result<()> {
        match &mut self.engine {
            Engine::Split { comms, .. } => comms.load_state(state),
            Engine::Fused { .. } => {
                bail!("comm residuals need split mode")
            }
        }
    }

    /// Gradient-only pass on one training batch (trace probes). Draws
    /// from the trainer's dedicated probe stream — NOT worker 0's — so
    /// interleaving probes with `train_step` never perturbs the
    /// training trajectory (regression-tested in
    /// `tests/runtime_integration.rs`).
    pub fn compute_grads(&mut self) -> Result<(f64, Vec<Tensor>)> {
        let batch = self.probe_source.next_train();
        match &self.engine {
            Engine::Split { grad_art, params, .. } => {
                grad_pass(grad_art, params, &batch.values)
            }
            Engine::Fused { .. } => bail!("compute_grads needs split mode"),
        }
    }

    /// One optimizer step. Returns the mean training loss across workers.
    pub fn train_step(&mut self) -> Result<f64> {
        self.step += 1;
        let lr = self.schedule.lr(self.step) as f32;
        match &mut self.engine {
            Engine::Split { grad_art, params, opt, comms, pool } => {
                // per-worker gradient (averaged over grad_accum microbatches)
                let mut worker_grads: Vec<Vec<Tensor>> =
                    Vec::with_capacity(self.cfg.workers);
                let mut loss_sum = 0.0;
                let grad_span = telemetry::span(Probe::Grad);
                for src in self.sources.iter_mut() {
                    let mut acc: Option<Vec<Tensor>> = None;
                    let mut wloss = 0.0;
                    for _ in 0..self.cfg.grad_accum {
                        let batch = src.next_train();
                        let (loss, grads) =
                            grad_pass(grad_art, params, &batch.values)?;
                        wloss += loss;
                        acc = Some(match acc {
                            None => grads,
                            Some(mut a) => {
                                for (t, g) in a.iter_mut().zip(&grads) {
                                    let d = t.data_mut();
                                    for (x, y) in d.iter_mut().zip(g.data()) {
                                        *x += y;
                                    }
                                }
                                a
                            }
                        });
                    }
                    let mut grads = acc.unwrap();
                    if self.cfg.grad_accum > 1 {
                        let inv = 1.0 / self.cfg.grad_accum as f32;
                        for t in grads.iter_mut() {
                            t.map_inplace(|v| v * inv);
                        }
                    }
                    loss_sum += wloss / self.cfg.grad_accum as f64;
                    worker_grads.push(grads);
                }
                drop(grad_span);
                // data-parallel combine: the compressed ring all-reduce
                // (comms subsystem — wire codec, error feedback, and
                // the modeled interconnect cost it reports); the
                // engine records its own pack/hop/unpack spans
                let comm_before =
                    telemetry::enabled().then(telemetry::thread_totals);
                let stats = comms
                    .allreduce_mean(&mut worker_grads)
                    .context("gradient all-reduce")?;
                self.last_comm_ms = stats.sim_overlap_seconds * 1e3;
                if let Some(before) = comm_before {
                    // calibrate the interconnect model from what this
                    // exchange actually measured: per-hop-sweep wire
                    // bytes/seconds fit the link line, pack + error
                    // feedback fit the staging bandwidth. Bitwise-inert:
                    // only the *modeled* comm_ms changes, never data.
                    let after = telemetry::thread_totals();
                    const HOPS: [Probe; 3] = [Probe::CommHopReduce,
                                              Probe::CommHopEncode,
                                              Probe::CommHopGather];
                    let hop_ns: u64 = HOPS.iter()
                        .map(|&p| after.ns(p).saturating_sub(before.ns(p)))
                        .sum();
                    let hop_n: u64 = HOPS.iter()
                        .map(|&p| after.spans(p) - before.spans(p))
                        .sum();
                    let stage_ns = after.ns(Probe::CommPack)
                        .saturating_sub(before.ns(Probe::CommPack))
                        + after.ns(Probe::CommFeedback)
                            .saturating_sub(before.ns(Probe::CommFeedback));
                    // cap the sample sets: the fit stabilizes quickly and
                    // the step loop must stay O(1) per step
                    const CAP: usize = 256;
                    if hop_n > 0 && hop_ns > 0
                        && self.comm_hop_samples.len() < CAP
                    {
                        self.comm_hop_samples.push((
                            stats.wire_bytes / hop_n as usize,
                            hop_ns as f64 / hop_n as f64 / 1e9,
                        ));
                    }
                    if stage_ns > 0 && self.comm_stage_samples.len() < CAP {
                        // every rank stages the full flat f32 buffer once
                        self.comm_stage_samples.push((
                            self.cfg.workers * self.meta.param_count * 4,
                            stage_ns as f64 / 1e9,
                        ));
                    }
                    comms.set_timing(TimingModel::from_measured(
                        &self.comm_hop_samples, &self.comm_stage_samples));
                    // report this step at the freshly calibrated model
                    self.last_comm_ms =
                        comms.modeled_overlap_seconds() * 1e3;
                }
                let grads = worker_grads.into_iter().next().unwrap();
                let opt_span = telemetry::span(Probe::OptStep);
                opt.step(params, &grads, lr);
                drop(opt_span);
                if telemetry::enabled() {
                    // live memory gauges, sampled at the step boundary
                    // and cross-checked against the static accountant
                    // (memory::opt_state_bytes mirrors state_bytes())
                    telemetry::gauge(Gauge::OptStateBytes,
                                     opt.state_bytes() as u64);
                    // tiled step-kernel decode/encode scratch: O(tile)
                    // per step thread at bf16/q8, zero at f32 (the f32
                    // kernels lend slot storage outright)
                    let scratch = if self.cfg.state_dtype == StateDtype::F32
                    {
                        0
                    } else {
                        2 * self.cfg.step_chunk * 4 * self.cfg.step_threads
                    };
                    telemetry::gauge(Gauge::StepScratchBytes,
                                     scratch as u64);
                    // the pool's live ledger: with every steady-state
                    // owner migrated, this equals the sum of the static
                    // accountant's figures (enforced in pool/memory
                    // tests across the optimizer × dtype × comm grid)
                    telemetry::gauge(Gauge::PoolBytes,
                                     pool.bytes_in_use() as u64);
                    telemetry::gauge(Gauge::PoolBytesPeak,
                                     pool.peak_bytes() as u64);
                }
                Ok(loss_sum / self.cfg.workers as f64)
            }
            Engine::Fused { train_art, state, n_params } => {
                self.last_comm_ms = 0.0;
                if self.cfg.workers != 1 || self.cfg.grad_accum != 1 {
                    bail!("fused mode runs single-worker, no accumulation \
                           (the optimizer lives inside the artifact)");
                }
                let batch = self.sources[0].next_train();
                let mut inputs = Vec::with_capacity(
                    state.len() + batch.values.len() + 1);
                inputs.extend(state.iter().cloned());
                inputs.extend(batch.values);
                inputs.push(HostValue::scalar_f32(lr));
                let outputs = train_art.execute(&inputs)?;
                // outputs: new_params ++ new_opt ++ loss
                let n_state = state.len();
                debug_assert!(*n_params <= n_state);
                let loss = outputs[n_state].scalar()? as f64;
                state.clone_from_slice(&outputs[..n_state]);
                Ok(loss)
            }
        }
    }

    /// Evaluate on the held-out set. Returns (loss, metric, metric2).
    pub fn evaluate(&self) -> Result<EvalRecord> {
        let src = &self.sources[0];
        let params = self.params_as_values();
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut total = 0.0;
        let mut top5 = 0.0;
        let n = src.eval_batches();
        for i in 0..n {
            let batch = src.eval_batch(i);
            let mut inputs = params.clone();
            inputs.extend(batch.values);
            let out = self.eval_art.execute(&inputs)?;
            loss_sum += out[0].scalar()? as f64;
            if out.len() >= 3 {
                correct += out[1].scalar()? as f64;
                total += out[2].scalar()? as f64;
                if self.meta.kind == "img" {
                    // outputs are (loss, top1, top5) counts per batch
                    top5 += out[2].scalar()? as f64;
                }
            }
        }
        let loss = loss_sum / n as f64;
        let (metric, metric2) = match self.meta.kind.as_str() {
            "mlm" => (Some(correct / total.max(1.0)), None),
            "img" => {
                let seen = (n * self.meta.batch) as f64;
                (Some(correct / seen), Some(top5 / seen))
            }
            "mt" => (self.bleu().ok().map(|b| b.bleu_smooth), None),
            _ => (None, None),
        };
        Ok(EvalRecord { step: self.step, loss, metric, metric2 })
    }

    /// Greedy-decode the eval set and score corpus BLEU (translation only).
    pub fn bleu(&self) -> Result<crate::metrics::BleuScore> {
        let decode = self.decode_art.as_ref()
            .ok_or_else(|| anyhow!("no decode artifact for {}", self.meta.kind))?;
        // references come from the typed MtSource
        let mt = self.sources[0]
            .as_any()
            .downcast_ref::<crate::data::translation::MtSource>()
            .ok_or_else(|| anyhow!("bleu() needs an MtSource"))?;
        let params = self.params_as_values();
        let mut hyps = Vec::new();
        let mut refs = Vec::new();
        let n = mt.eval_batches();
        for i in 0..n {
            let batch = mt.eval_batch(i);
            let mut inputs = params.clone();
            inputs.push(batch.values[0].clone()); // src tokens only
            let out = decode.execute(&inputs)?;
            let tokens = out[0].as_i32()?;
            let l = out[0].shape()[1];
            for b in 0..self.meta.batch {
                hyps.push(trim_ref(&tokens[b * l..(b + 1) * l]));
            }
            refs.extend(mt.references(i).iter().cloned());
        }
        Ok(corpus_bleu(&hyps, &refs))
    }

    fn params_as_values(&self) -> Vec<HostValue> {
        match &self.engine {
            Engine::Split { params, .. } => {
                params.iter().map(|t| HostValue::F32(t.clone())).collect()
            }
            Engine::Fused { state, n_params, .. } => {
                state[..*n_params].to_vec()
            }
        }
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Save current params + optimizer state as a versioned `SM3CKPT2`
    /// checkpoint (split mode; the fused engine's state lives inside the
    /// artifact). Params are always f32-tagged; optimizer slots carry the
    /// engine's storage dtype, so a `state_dtype = "q8"` run writes its
    /// state ~4× smaller — except scalar slots (Adam's step counter `t`),
    /// which stay f32 per the DESIGN.md §8 contract. Compressed-comm
    /// runs additionally write their per-rank error-feedback residuals
    /// (`comm/residual/<rank>`, f32-tagged — residuals must stay exact
    /// for resume to be bitwise; see DESIGN.md §12). Residuals only
    /// mutate inside the all-reduce, so any between-steps save — during
    /// gradient accumulation included — captures a consistent snapshot.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>)
                           -> Result<()> {
        let _span = telemetry::span(Probe::CkptIo);
        let Engine::Split { params, opt, comms, .. } = &self.engine else {
            bail!("checkpoint save needs split mode (the fused artifact \
                   owns its optimizer state)");
        };
        // params are borrowed, not cloned — only the optimizer-state
        // tensors (already owned clones from `Optimizer::state`) need a
        // side vec, so saving never doubles parameter memory
        let dtype = opt.state_dtype();
        let state: Vec<(String, Tensor, StateDtype)> = opt
            .state()
            .into_iter()
            .map(|(leaf, slot, t)| {
                let tag = if t.len() <= 1 { StateDtype::F32 } else { dtype };
                (format!("opt/{leaf}/{slot}"), t, tag)
            })
            .collect();
        let residuals: Vec<(String, Tensor)> = comms
            .state()
            .into_iter()
            .map(|(rank, t)| (format!("comm/residual/{rank}"), t))
            .collect();
        let mut entries: Vec<(String, &Tensor, StateDtype)> =
            Vec::with_capacity(params.len() + state.len()
                               + residuals.len());
        for (i, t) in params.iter().enumerate() {
            entries.push((format!("param/{}", self.meta.params[i].name), t,
                          StateDtype::F32));
        }
        for (n, t, d) in &state {
            entries.push((n.clone(), t, *d));
        }
        for (n, t) in &residuals {
            entries.push((n.clone(), t, StateDtype::F32));
        }
        crate::checkpoint::save_v2(path, &entries)
    }

    /// Snapshot of everything this trainer's thread has measured so far
    /// (per-phase spans, comm counters, memory gauges), under canonical
    /// probe names. Benches fold this into their `BENCH_*.json` docs.
    pub fn telemetry_registry(&self) -> telemetry::Registry {
        let mut reg = telemetry::Registry::new();
        telemetry::thread_snapshot_into(&mut reg);
        reg
    }

    /// Run the configured number of steps with periodic eval. Fills the
    /// per-phase `*_ms` columns from telemetry snapshot deltas around
    /// each step, and — when `cfg.telemetry_jsonl` is set — streams one
    /// JSONL event per step plus a final aggregate summary event.
    pub fn train(&mut self) -> Result<RunHistory> {
        let mut hist = RunHistory::default();
        let mut jsonl = match &self.cfg.telemetry_jsonl {
            Some(path) => Some(telemetry::JsonlWriter::create(path)
                .context("opening telemetry_jsonl")?),
            None => None,
        };
        for _ in 0..self.cfg.steps {
            let before = telemetry::thread_totals();
            let t0 = std::time::Instant::now();
            let loss = self.train_step()?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let ema = self.ema.update(loss);
            let after = telemetry::thread_totals();
            let rec = StepRecord {
                step: self.step,
                loss,
                loss_ema: ema,
                lr: self.schedule.lr(self.step),
                wall_ms,
                comm_ms: self.last_comm_ms,
                grad_ms: after.ms_since(&before, &[Probe::Grad]),
                opt_ms: after.ms_since(&before, &[Probe::OptStep]),
                comm_pack_ms: after.ms_since(
                    &before, &[Probe::CommPack, Probe::CommFeedback]),
                comm_hop_ms: after.ms_since(
                    &before,
                    &[Probe::CommHopReduce, Probe::CommHopEncode,
                      Probe::CommHopGather]),
                comm_unpack_ms: after.ms_since(
                    &before, &[Probe::CommUnpack]),
                ckpt_ms: after.ms_since(&before, &[Probe::CkptIo]),
            };
            // the watchdogs see this step's telemetry deltas (read-only
            // bookkeeping — the trajectory is untouched, proptested)
            let health = self.observe_health(&rec, &before, &after);
            if let Some(w) = jsonl.as_mut() {
                w.event(&step_event_with_health(&rec, &health))
                    .context("writing telemetry_jsonl step event")?;
            }
            // drain the trace rings at the step boundary (quiescent:
            // workers are joined, the hop worker is idle)
            if let Some(tl) = self.timeline.as_mut() {
                tl.drain();
            }
            hist.steps.push(rec);
            if !health.ok() {
                eprintln!("[health] {}", health.report());
            }
            if self.health.must_abort(&health) {
                // flush what the rings hold before halting, so the
                // post-mortem trace covers the tripping step
                self.write_trace()
                    .context("writing trace_out after health abort")?;
                bail!("run halted by health watchdog: {}",
                      health.report());
            }
            if self.step % self.cfg.eval_every == 0
                || self.step == self.cfg.steps
            {
                let eval_span = telemetry::span(Probe::Eval);
                let ev = self.evaluate()?;
                drop(eval_span);
                hist.evals.push(ev);
            }
        }
        if let Some(w) = jsonl.as_mut() {
            // end-of-run aggregate: every span/counter/gauge this thread
            // accumulated, under canonical names
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("type".to_string(),
                       Json::String("summary".to_string()));
            obj.insert("registry".to_string(),
                       self.telemetry_registry().to_json());
            w.event(&Json::Object(obj))
                .context("writing telemetry_jsonl summary event")?;
            w.flush().context("flushing telemetry_jsonl")?;
        }
        self.write_trace().context("writing trace_out")?;
        Ok(hist)
    }

    /// Build this step's watchdog observations from the telemetry
    /// deltas and run every rule. Works with telemetry off too — the
    /// counters/hops/pool sides are simply absent and the loss window
    /// still guards divergence.
    fn observe_health(&mut self, rec: &StepRecord,
                      before: &telemetry::Totals,
                      after: &telemetry::Totals) -> RunHealth {
        let mut obs = StepObs {
            step: rec.step,
            loss: rec.loss,
            grad_nonfinite: after.counter(Counter::GradNonFinite)
                .saturating_sub(before.counter(Counter::GradNonFinite)),
            update_nonfinite: after.counter(Counter::UpdateNonFinite)
                .saturating_sub(before.counter(Counter::UpdateNonFinite)),
            ..StepObs::default()
        };
        const HOPS: [Probe; 3] = [Probe::CommHopReduce,
                                  Probe::CommHopEncode,
                                  Probe::CommHopGather];
        let hop_ns: u64 = HOPS.iter()
            .map(|&p| after.ns(p).saturating_sub(before.ns(p)))
            .sum();
        let hop_n: u64 = HOPS.iter()
            .map(|&p| after.spans(p).saturating_sub(before.spans(p)))
            .sum();
        let wire = after.counter(Counter::CommWireBytes)
            .saturating_sub(before.counter(Counter::CommWireBytes));
        if let Engine::Split { opt, comms, pool, .. } = &self.engine {
            if hop_n > 0 {
                // measured mean hop vs the calibrated model's
                // prediction for the same per-hop payload
                let timing = comms.timing();
                let per_hop_bytes = wire as f64 / hop_n as f64;
                obs.hop_mean_ns = Some(hop_ns as f64 / hop_n as f64);
                obs.hop_expect_ns = Some(
                    (timing.hop_latency
                        + per_hop_bytes / timing.link_bandwidth) * 1e9);
            }
            if telemetry::enabled() {
                // live pool occupancy vs the object accounting the PR 9
                // pool tests pin to the static accountant
                let scratch =
                    if self.cfg.state_dtype == StateDtype::F32 {
                        0
                    } else {
                        2 * self.cfg.step_chunk * 4 * self.cfg.step_threads
                    };
                let accounted = opt.state_bytes() + scratch
                    + comms.buffer_bytes() + comms.scratch_bytes();
                obs.pool_bytes = Some(pool.bytes_in_use() as u64);
                obs.accountant_bytes = Some(accounted as u64);
            }
        }
        self.health.observe(&obs)
    }

    /// Drain any remaining trace records and write the accumulated
    /// timeline as Chrome-trace JSON to `cfg.trace_out`. No-op without
    /// `trace_out`; idempotent (the abort path flushes early).
    fn write_trace(&mut self) -> Result<()> {
        let (Some(tl), Some(path)) =
            (self.timeline.as_mut(), self.cfg.trace_out.as_deref())
        else {
            return Ok(());
        };
        tl.drain();
        let doc = tl.to_chrome_json();
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {parent:?}"))?;
            }
        }
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing {path}"))?;
        Ok(())
    }
}

/// The per-step JSONL event (`{"type":"step",...}`) mirroring the
/// loss-curve CSV row — schema documented in EXPERIMENTS.md §Telemetry.
fn step_event(r: &StepRecord) -> Json {
    let mut o = std::collections::BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        o.insert(k.to_string(), v);
    };
    put("type", Json::String("step".to_string()));
    put("step", Json::Number(r.step as f64));
    put("loss", Json::Number(r.loss));
    put("loss_ema", Json::Number(r.loss_ema));
    put("lr", Json::Number(r.lr));
    put("wall_ms", Json::Number(r.wall_ms));
    put("comm_ms", Json::Number(r.comm_ms));
    put("grad_ms", Json::Number(r.grad_ms));
    put("opt_ms", Json::Number(r.opt_ms));
    put("comm_pack_ms", Json::Number(r.comm_pack_ms));
    put("comm_hop_ms", Json::Number(r.comm_hop_ms));
    put("comm_unpack_ms", Json::Number(r.comm_unpack_ms));
    put("ckpt_ms", Json::Number(r.ckpt_ms));
    Json::Object(o)
}

/// The step event plus the step's health verdict
/// (`"health": {verdict, rules: [...]}`) — additive over the PR 7
/// schema, so existing consumers are untouched.
fn step_event_with_health(r: &StepRecord, h: &RunHealth) -> Json {
    let Json::Object(mut o) = step_event(r) else {
        unreachable!("step_event returns an object");
    };
    o.insert("health".to_string(), h.to_json());
    Json::Object(o)
}

/// Execute a grad artifact: inputs `params ++ batch`, outputs
/// `(loss, grads...)`.
fn grad_pass(art: &Artifact, params: &[Tensor], batch: &[HostValue])
             -> Result<(f64, Vec<Tensor>)> {
    let mut inputs: Vec<HostValue> =
        params.iter().map(|t| HostValue::F32(t.clone())).collect();
    inputs.extend(batch.iter().cloned());
    let mut out = art.execute(&inputs)?;
    let loss = out[0].scalar()? as f64;
    let grads = out
        .drain(1..)
        .map(HostValue::into_f32)
        .collect::<Result<Vec<_>>>()?;
    Ok((loss, grads))
}

/// Load `<model>_init.ckpt` (exported by aot.py) in manifest param order.
fn load_init_params(dir: &str, meta: &ModelMeta) -> Result<Vec<Tensor>> {
    let path = std::path::Path::new(dir).join(format!("{}_init.ckpt", meta.name));
    let loaded = crate::checkpoint::load(&path)?;
    let by_name: std::collections::HashMap<String, Tensor> =
        loaded.into_iter().collect();
    meta.params
        .iter()
        .map(|e| {
            let t = by_name.get(&e.name).ok_or_else(|| {
                anyhow!("{path:?} missing tensor {}", e.name)
            })?;
            if t.shape() != e.shape.as_slice() {
                bail!("{}: checkpoint shape {:?} != manifest {:?}",
                      e.name, t.shape(), e.shape);
            }
            Ok(t.clone())
        })
        .collect()
}

/// Zero-initialized optimizer state for a fused artifact, in input order.
/// (JAX inits every slot with `jnp.zeros`, including Adam's step count.)
fn fused_initial_state(art: &Artifact, params: Vec<Tensor>)
                       -> Result<Vec<HostValue>> {
    let spec = art.spec();
    let mut state: Vec<HostValue> =
        params.into_iter().map(HostValue::F32).collect();
    let opt_idx = spec.input_range("opt");
    for &i in &opt_idx {
        let e = &spec.inputs[i];
        if i != state.len() {
            bail!("fused artifact inputs out of order at {}", e.name);
        }
        state.push(HostValue::F32(Tensor::zeros(&e.shape)));
    }
    Ok(state)
}

