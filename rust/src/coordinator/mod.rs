//! The training coordinator — Layer 3's centerpiece.
//!
//! Owns the whole training run: loads AOT artifacts through the PJRT
//! [`Runtime`], shards the synthetic data across logical workers, runs the
//! step loop on either execution path, reduces worker gradients with the
//! ring all-reduce, applies the LR schedule, evaluates, and logs curves.
//!
//! Two execution paths (DESIGN.md §2):
//!
//! * **Split** — the artifact computes `(loss, grads)`; the pure-Rust
//!   `optim::` bank applies the update. One artifact serves every
//!   optimizer; optimizer state is introspectable (traces, checkpoints);
//!   gradient accumulation gives arbitrary effective batch sizes (the
//!   Fig. 3-right batch-size sweep).
//! * **Fused** — the artifact is the whole train step with the Layer-1
//!   Pallas optimizer kernel inside; host code only shuttles state.
//!
//! Workers are *logical ranks*: each has an independent data shard and
//! its gradients join through the `comms` subsystem's chunked ring
//! all-reduce (DESIGN.md §12) in schedule order, so the arithmetic (and
//! hence the loss curve) is exactly what a pod run would produce; the
//! exchange itself can compress its wire payloads (`comm_dtype`) and
//! fan out over host threads (`comm_threads`) without changing a bit.
//! The forward/backward passes of the ranks execute sequentially on the
//! one physical CPU; the simulated interconnect cost of each exchange
//! is reported per step as `comm_ms` (`comms::TimingModel`).

mod trainer;

pub use trainer::{EvalRecord, RunHistory, StepRecord, Trainer};

use crate::config::TrainConfig;
use crate::optim::schedule::{paper_default_with, Schedule};

/// Resolve the schedule from config (paper Table 4 defaults by optimizer
/// unless the config overrides the shape). Staircase parameters come
/// from `[optim] lr_eta0 / lr_alpha / lr_tau` (defaults preserved);
/// unknown schedule names and out-of-range parameters are errors — the
/// old silent fallback to a constant schedule hid config typos.
pub fn schedule_for(cfg: &TrainConfig, d_model: usize)
                    -> anyhow::Result<Schedule> {
    let stair = cfg.optim.staircase_params();
    match cfg.optim.schedule.as_str() {
        "paper" => paper_default_with(&cfg.optim.name, cfg.optim.lr,
                                      cfg.optim.warmup_steps, d_model,
                                      cfg.steps, &stair),
        name => Schedule::from_name_with(name, cfg.optim.lr,
                                         cfg.optim.warmup_steps, d_model,
                                         cfg.steps, &stair),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    #[test]
    fn schedule_resolution() {
        let mut cfg = TrainConfig::default();
        cfg.optim.schedule = "paper".into();
        cfg.optim.name = "sm3".into();
        let s = schedule_for(&cfg, 128).unwrap();
        assert_eq!(s.lr(10_000), cfg.optim.lr); // constant past warmup

        cfg.optim.name = "adam".into();
        let s = schedule_for(&cfg, 128).unwrap();
        assert!(s.lr(50_000) < s.lr(200)); // rsqrt decays
    }

    #[test]
    fn schedule_resolution_uses_config_staircase_params() {
        let mut cfg = TrainConfig::default();
        cfg.optim.schedule = "staircase".into();
        cfg.optim.lr = 1.0;
        cfg.optim.warmup_steps = 0;
        cfg.optim.lr_alpha = 0.5;
        cfg.optim.lr_tau = Some(100);
        cfg.optim.lr_eta0 = Some(0.125);
        let s = schedule_for(&cfg, 128).unwrap();
        assert_eq!(s.lr(50), 1.0);
        assert_eq!(s.lr(150), 0.5);
        assert_eq!(s.lr(1_000_000), 0.125); // the configured floor
        // invalid alpha is an error, not a silent constant schedule
        cfg.optim.lr_alpha = 1.5;
        assert!(schedule_for(&cfg, 128).is_err());
        // unknown names error too
        cfg.optim.lr_alpha = 0.5;
        cfg.optim.schedule = "cosine".into();
        assert!(schedule_for(&cfg, 128).is_err());
    }
}
