//! Optional JSONL event stream: one JSON object per line, serialized
//! through the crate's own [`crate::json`] value model so the schema
//! round-trips through the same parser that reads artifact manifests.
//!
//! The trainer emits one `step` event per training step (per-phase
//! millisecond deltas) and a final `summary` event holding the folded
//! [`super::Registry`]. The stream is opt-in (`telemetry_jsonl` /
//! `--telemetry-jsonl`) and lives entirely off the hot path: events are
//! built and written on the coordinator thread between steps.

use crate::json::Json;
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};

/// Line-oriented JSON event writer (`*.jsonl`).
#[derive(Debug)]
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    /// Create (truncate) the event stream at `path`, creating parent
    /// directories as needed.
    pub fn create(path: &str) -> Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating telemetry jsonl {path}"))?;
        Ok(JsonlWriter { out: BufWriter::new(f) })
    }

    /// Append one event as a single line.
    pub fn event(&mut self, v: &Json) -> Result<()> {
        writeln!(self.out, "{v}").context("writing telemetry jsonl event")
    }

    /// Flush buffered events to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush().context("flushing telemetry jsonl")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn events_round_trip_through_the_parser() {
        let dir = std::env::temp_dir().join("sm3_telemetry_jsonl");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let path = path.to_str().unwrap();

        let mut events = Vec::new();
        for step in 0..3u64 {
            let mut o = BTreeMap::new();
            o.insert("type".into(), Json::String("step".into()));
            o.insert("step".into(), Json::Number(step as f64));
            o.insert("grad_ms".into(), Json::Number(0.25 * step as f64));
            o.insert("note".into(),
                     Json::String("quotes \" and \\ and\nnewlines".into()));
            events.push(Json::Object(o));
        }
        let mut w = JsonlWriter::create(path).unwrap();
        for e in &events {
            w.event(e).unwrap();
        }
        w.flush().unwrap();
        drop(w);

        let text = std::fs::read_to_string(path).unwrap();
        let parsed: Vec<Json> = text
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(parsed, events, "JSONL must round-trip bit-exactly");
    }
}
