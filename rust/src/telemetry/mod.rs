//! Low-overhead, determinism-neutral instrumentation (DESIGN.md §14).
//!
//! Three pieces:
//!
//! * **Hot path** — a fixed set of instrument points ([`Probe`],
//!   [`Counter`], [`Gauge`]) backed by const-initialized *thread-local*
//!   cells. Recording a span is two monotonic clock reads and a handful
//!   of `Cell` stores: no locks, no atomics on the data path, and no
//!   heap allocation whether telemetry is enabled or disabled — so the
//!   steady-state allocation-free gates (optimizer steps, comm
//!   exchanges) hold with telemetry in either state.
//! * **Cold path** — [`Registry`]: string-keyed per-phase aggregates
//!   (min/mean/max/total, counts, gauges) folded from thread cells at
//!   step/run boundaries, exported as `BENCH_*.json` or a JSONL event
//!   stream ([`JsonlWriter`]).
//! * **Clock** — pluggable via [`Clock`]: monotonic in production,
//!   [`FakeClock`] injected in tests.
//!
//! The determinism contract: telemetry only *reads* clocks and *writes*
//! integer cells. It never touches f32 training arithmetic, gradient
//! buffers, RNG state, or allocation on measured paths — so trajectories
//! are bitwise identical with telemetry on, off, or absent, which the
//! proptest gate (`proptest::tests::telemetry_on_off_bitwise`) asserts
//! across optimizers × state dtypes × sharding × comm dtypes × backends.
//!
//! Worker threads (sharded optimizer steps, threaded comm hops) are
//! spawned in scopes that end inside a step, so their thread-locals are
//! unreachable afterwards. Instrumented scopes therefore measure into
//! preallocated per-worker slots and the *owning* thread folds them —
//! in worker-index order — into its own cells after the scope joins
//! ("merged at step boundaries").

pub mod clock;
pub mod jsonl;
pub mod registry;
pub mod trace_event;

pub use clock::{now_ns, Clock, FakeClock, MonotonicClock};
pub use jsonl::JsonlWriter;
pub use registry::{
    bench_doc, validate_bench_doc, GaugeStats, Registry, SpanStats,
    BENCH_SCHEMA,
};
pub use trace_event::{
    enable_tracing, tracing, validate_trace_doc, Timeline, TracingGuard,
    TRACE_SCHEMA,
};

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

// ---------------------------------------------------------------------------
// Instrument points

/// Timed phases on the training hot path. The set is fixed so the
/// per-thread storage is a flat array — no hashing or allocation when a
/// span records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Probe {
    /// Forward+backward pass (all workers, all grad-accum slices).
    Grad = 0,
    /// Optimizer update (`Optimizer::step`), end to end.
    OptStep = 1,
    /// One sharded-optimizer worker's bucket (recorded per worker,
    /// folded after the scope joins).
    OptWorker = 2,
    /// Gather per-worker grads into the comm engine's flat buffers.
    CommPack = 3,
    /// Error-feedback staging (compressed wire dtypes only).
    CommFeedback = 4,
    /// One reduce-scatter hop sweep of the ring schedule.
    CommHopReduce = 5,
    /// The finalize (re-encode) sweep of the ring schedule.
    CommHopEncode = 6,
    /// One all-gather (decode/copy) hop sweep of the ring schedule.
    CommHopGather = 7,
    /// Scatter reduced flat buffers back to per-worker grads.
    CommUnpack = 8,
    /// Held-out evaluation pass.
    Eval = 9,
    /// Checkpoint serialization and file I/O.
    CkptIo = 10,
}

impl Probe {
    /// Number of probes (size of the per-thread span array).
    pub const COUNT: usize = 11;

    /// Every probe, in index order.
    pub const ALL: [Probe; Probe::COUNT] = [
        Probe::Grad,
        Probe::OptStep,
        Probe::OptWorker,
        Probe::CommPack,
        Probe::CommFeedback,
        Probe::CommHopReduce,
        Probe::CommHopEncode,
        Probe::CommHopGather,
        Probe::CommUnpack,
        Probe::Eval,
        Probe::CkptIo,
    ];

    /// Canonical registry/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            Probe::Grad => "grad",
            Probe::OptStep => "opt_step",
            Probe::OptWorker => "opt_worker",
            Probe::CommPack => "comm/pack",
            Probe::CommFeedback => "comm/feedback",
            Probe::CommHopReduce => "comm/hop_reduce",
            Probe::CommHopEncode => "comm/hop_encode",
            Probe::CommHopGather => "comm/hop_gather",
            Probe::CommUnpack => "comm/unpack",
            Probe::Eval => "eval",
            Probe::CkptIo => "ckpt_io",
        }
    }
}

/// Monotone hot-path counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Counter {
    /// Simulated wire bytes moved by completed all-reduce exchanges.
    CommWireBytes = 0,
    /// Completed all-reduce exchanges.
    CommExchanges = 1,
    /// Non-finite (NaN/Inf) gradient values observed on instrumented
    /// paths: the comm-pack scan and the chunk-kernel tile scan. Fed to
    /// the health watchdogs (`health::NonFiniteRule`).
    GradNonFinite = 2,
    /// Non-finite (NaN/Inf) parameter values observed immediately after
    /// a chunk-kernel tile update — contamination reached the weights.
    UpdateNonFinite = 3,
}

impl Counter {
    /// Number of counters (size of the per-thread counter array).
    pub const COUNT: usize = 4;

    /// Every counter, in index order.
    pub const ALL: [Counter; Counter::COUNT] = [
        Counter::CommWireBytes,
        Counter::CommExchanges,
        Counter::GradNonFinite,
        Counter::UpdateNonFinite,
    ];

    /// Canonical registry/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            Counter::CommWireBytes => "comm/wire_bytes",
            Counter::CommExchanges => "comm/exchanges",
            Counter::GradNonFinite => "grad/nonfinite",
            Counter::UpdateNonFinite => "opt/update_nonfinite",
        }
    }
}

/// Live memory / balance gauges, cross-checked against the static
/// accountant (`memory::`) at step boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Gauge {
    /// Optimizer slot bytes at the configured state dtype
    /// (`Optimizer::state_bytes`, mirrors `memory::opt_state_bytes`).
    OptStateBytes = 0,
    /// Comm engine flat + residual buffer bytes
    /// (`CommEngine::buffer_bytes`, mirrors `memory::comm_buffer_bytes`).
    CommBufferBytes = 1,
    /// Error-feedback residual bytes (`residual_floats * 4`).
    CommResidualBytes = 2,
    /// Step-kernel decode/encode scratch bytes (O(tile), zero at f32).
    StepScratchBytes = 3,
    /// Sharded-step load imbalance, permille: slowest worker over mean
    /// worker time × 1000 (1000 = perfectly balanced).
    OptImbalancePermille = 4,
    /// Gradient buckets concurrently in flight inside an exchange:
    /// peaks at 2 under `comm_overlap` (hop lane + stager), pinned at 1
    /// on the serial bucket loop.
    CommInflightBuckets = 5,
    /// Live bytes leased from the memory pool across every tag
    /// (`Pool::bytes_in_use`; equals the static accountant's
    /// steady-state total when the pool owns all buffers).
    PoolBytes = 6,
    /// High-water mark of pool occupancy since construction
    /// (`Pool::peak_bytes`) — the figure the CI memory gate budgets.
    PoolBytesPeak = 7,
}

impl Gauge {
    /// Number of gauges (size of the per-thread gauge array).
    pub const COUNT: usize = 8;

    /// Every gauge, in index order.
    pub const ALL: [Gauge; Gauge::COUNT] = [
        Gauge::OptStateBytes,
        Gauge::CommBufferBytes,
        Gauge::CommResidualBytes,
        Gauge::StepScratchBytes,
        Gauge::OptImbalancePermille,
        Gauge::CommInflightBuckets,
        Gauge::PoolBytes,
        Gauge::PoolBytesPeak,
    ];

    /// Canonical registry/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::OptStateBytes => "mem/opt_state_bytes",
            Gauge::CommBufferBytes => "mem/comm_buffer_bytes",
            Gauge::CommResidualBytes => "mem/comm_residual_bytes",
            Gauge::StepScratchBytes => "mem/step_scratch_bytes",
            Gauge::OptImbalancePermille => "opt/imbalance_permille",
            Gauge::CommInflightBuckets => "comm/inflight_buckets",
            Gauge::PoolBytes => "mem/pool_bytes",
            Gauge::PoolBytesPeak => "mem/pool_bytes_peak",
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-local cells

struct SpanCell {
    count: Cell<u64>,
    total_ns: Cell<u64>,
    min_ns: Cell<u64>,
    max_ns: Cell<u64>,
}

impl SpanCell {
    const INIT: SpanCell = SpanCell {
        count: Cell::new(0),
        total_ns: Cell::new(0),
        min_ns: Cell::new(u64::MAX),
        max_ns: Cell::new(0),
    };

    #[inline]
    fn record(&self, ns: u64) {
        self.count.set(self.count.get() + 1);
        self.total_ns.set(self.total_ns.get() + ns);
        self.min_ns.set(self.min_ns.get().min(ns));
        self.max_ns.set(self.max_ns.get().max(ns));
    }

    fn stats(&self) -> SpanStats {
        SpanStats {
            count: self.count.get(),
            total_ns: self.total_ns.get(),
            min_ns: self.min_ns.get(),
            max_ns: self.max_ns.get(),
        }
    }

    fn reset(&self) {
        self.count.set(0);
        self.total_ns.set(0);
        self.min_ns.set(u64::MAX);
        self.max_ns.set(0);
    }
}

struct GaugeCell {
    last: Cell<u64>,
    peak: Cell<u64>,
}

impl GaugeCell {
    const INIT: GaugeCell =
        GaugeCell { last: Cell::new(0), peak: Cell::new(0) };

    #[inline]
    fn set(&self, v: u64) {
        self.last.set(v);
        self.peak.set(self.peak.get().max(v));
    }

    fn stats(&self) -> GaugeStats {
        GaugeStats { last: self.last.get(), peak: self.peak.get() }
    }

    fn reset(&self) {
        self.last.set(0);
        self.peak.set(0);
    }

    /// Re-arm the high-water mark at the current level (a new bench
    /// run's peak starts from its own live value, not a predecessor's).
    fn rearm(&self) {
        self.peak.set(self.last.get());
    }
}

struct Cells {
    spans: [SpanCell; Probe::COUNT],
    counters: [Cell<u64>; Counter::COUNT],
    gauges: [GaugeCell; Gauge::COUNT],
}

impl Cells {
    const ZERO: Cell<u64> = Cell::new(0);
    const NEW: Cells = Cells {
        spans: [SpanCell::INIT; Probe::COUNT],
        counters: [Cells::ZERO; Counter::COUNT],
        gauges: [GaugeCell::INIT; Gauge::COUNT],
    };
}

thread_local! {
    // const-initialized and Drop-free, so first touch from a hot loop
    // neither allocates nor registers a TLS destructor
    static CELLS: Cells = const { Cells::NEW };
}

// ---------------------------------------------------------------------------
// Enablement

// Guard count rather than a plain bool: overlapping scopes (parallel
// tests, trainer + bench in one process) compose instead of clobbering
// each other. Relaxed is enough — the flag gates only instrumentation.
static ENABLED: AtomicUsize = AtomicUsize::new(0);

/// True while at least one [`Enabled`] guard is alive.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed) > 0
}

/// RAII enablement guard — telemetry records while it lives.
#[derive(Debug)]
pub struct Enabled {
    _priv: (),
}

/// Turn telemetry on until the returned guard drops. Guards nest.
#[must_use = "telemetry stays enabled only while the guard lives"]
pub fn enable() -> Enabled {
    ENABLED.fetch_add(1, Ordering::Relaxed);
    Enabled { _priv: () }
}

impl Drop for Enabled {
    fn drop(&mut self) {
        ENABLED.fetch_sub(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Hot-path recording

/// RAII span: times from construction to drop and records into the
/// dropping thread's cell for `probe`. When telemetry is disabled at
/// construction this is a no-op shell (no clock read, no store).
#[derive(Debug)]
pub struct Span {
    probe: Probe,
    t0_ns: u64,
    live: bool,
}

/// Open a span for `probe` (see [`Span`]).
#[inline]
#[must_use = "a span records when dropped; binding it to _ drops immediately"]
pub fn span(probe: Probe) -> Span {
    if enabled() {
        Span { probe, t0_ns: clock::now_ns(), live: true }
    } else {
        Span { probe, t0_ns: 0, live: false }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live {
            let dur = clock::now_ns().saturating_sub(self.t0_ns);
            record_ns(self.probe, dur);
            // one ring-buffer entry when the per-event timeline is on
            // (a relaxed load and early return otherwise)
            trace_event::complete(self.probe, self.t0_ns, dur);
        }
    }
}

/// Record a span duration directly (used when a worker measured into a
/// preallocated slot and the owner folds it in after the scope joins).
/// Unconditional — callers gate on [`enabled`].
#[inline]
pub fn record_ns(probe: Probe, ns: u64) {
    // try_with: TLS may be gone during thread teardown — drop the
    // sample rather than panicking inside a destructor
    let _ = CELLS.try_with(|c| c.spans[probe as usize].record(ns));
}

/// Add `n` to `counter` on this thread (no-op while disabled).
#[inline]
pub fn count(counter: Counter, n: u64) {
    if enabled() {
        let _ = CELLS.try_with(|c| {
            let cell = &c.counters[counter as usize];
            cell.set(cell.get() + n);
        });
        trace_event::instant_counter(counter, n);
    }
}

/// Sample `gauge` on this thread, keeping its high-water mark (no-op
/// while disabled).
#[inline]
pub fn gauge(gauge: Gauge, v: u64) {
    if enabled() {
        let _ = CELLS.try_with(|c| c.gauges[gauge as usize].set(v));
        trace_event::instant_gauge(gauge, v);
    }
}

// ---------------------------------------------------------------------------
// Snapshots

/// Copyable snapshot of this thread's span totals and counters; two
/// snapshots subtract into per-step phase deltas (the widened
/// `StepRecord` columns).
#[derive(Clone, Copy, Debug, Default)]
pub struct Totals {
    span_ns: [u64; Probe::COUNT],
    span_count: [u64; Probe::COUNT],
    counters: [u64; Counter::COUNT],
}

impl Totals {
    /// Accumulated nanoseconds for `probe`.
    pub fn ns(&self, probe: Probe) -> u64 {
        self.span_ns[probe as usize]
    }

    /// Recorded span count for `probe`.
    pub fn spans(&self, probe: Probe) -> u64 {
        self.span_count[probe as usize]
    }

    /// Counter value.
    pub fn counter(&self, counter: Counter) -> u64 {
        self.counters[counter as usize]
    }

    /// Milliseconds accumulated across `probes` since the `earlier`
    /// snapshot (0.0 while telemetry is disabled: nothing accumulates).
    pub fn ms_since(&self, earlier: &Totals, probes: &[Probe]) -> f64 {
        probes
            .iter()
            .map(|&p| self.ns(p).saturating_sub(earlier.ns(p)))
            .sum::<u64>() as f64
            / 1e6
    }
}

/// Snapshot this thread's totals (cheap: a fixed-size copy).
pub fn thread_totals() -> Totals {
    CELLS
        .try_with(|c| {
            let mut t = Totals::default();
            for p in Probe::ALL {
                t.span_ns[p as usize] = c.spans[p as usize].total_ns.get();
                t.span_count[p as usize] = c.spans[p as usize].count.get();
            }
            for k in Counter::ALL {
                t.counters[k as usize] = c.counters[k as usize].get();
            }
            t
        })
        .unwrap_or_default()
}

/// This thread's current value/high-water for `gauge`.
pub fn thread_gauge(gauge: Gauge) -> GaugeStats {
    CELLS
        .try_with(|c| c.gauges[gauge as usize].stats())
        .unwrap_or_default()
}

/// Fold this thread's cells into `reg` under the canonical probe /
/// counter / gauge names. Empty cells are skipped so an untouched
/// subsystem adds no keys.
pub fn thread_snapshot_into(reg: &mut Registry) {
    let _ = CELLS.try_with(|c| {
        for p in Probe::ALL {
            let s = c.spans[p as usize].stats();
            if s.count > 0 {
                reg.merge_span(p.name(), &s);
            }
        }
        for k in Counter::ALL {
            let n = c.counters[k as usize].get();
            if n > 0 {
                reg.add(k.name(), n);
            }
        }
        for g in Gauge::ALL {
            let s = c.gauges[g as usize].stats();
            if s.peak > 0 {
                reg.merge_gauge(g.name(), &s);
            }
        }
    });
}

/// Re-arm this thread's gauge high-water marks at their current levels
/// — the per-thread half of [`Registry::reset_run`]. Call between bench
/// configs driven by one process, so a later section's exported peaks
/// (`mem/pool_bytes_peak`, `comm/inflight_buckets`) describe that
/// section alone instead of leaking an earlier, larger config's
/// high-water mark (ISSUE 10 satellite). Span and counter cells are
/// untouched: those are cumulative trajectory totals by design.
pub fn reset_thread_run() {
    let _ = CELLS.try_with(|c| {
        for g in &c.gauges {
            g.rearm();
        }
    });
}

/// Zero this thread's cells (start of a run or of a test).
pub fn reset_thread() {
    let _ = CELLS.try_with(|c| {
        for s in &c.spans {
            s.reset();
        }
        for k in &c.counters {
            k.set(0);
        }
        for g in &c.gauges {
            g.reset();
        }
    });
}

// ---------------------------------------------------------------------------
// Injected-clock spans (explicit layer, used by tests and bench_util)

/// A span timed against an explicit [`Clock`] and stopped by hand —
/// the injectable counterpart of the thread-local [`span`] API.
#[derive(Debug)]
pub struct ScopedSpan<'a> {
    clock: &'a dyn Clock,
    t0_ns: u64,
}

impl<'a> ScopedSpan<'a> {
    /// Start timing now on `clock`.
    pub fn start(clock: &'a dyn Clock) -> Self {
        ScopedSpan { clock, t0_ns: clock.now_ns() }
    }

    /// Elapsed nanoseconds without stopping.
    pub fn elapsed_ns(&self) -> u64 {
        self.clock.now_ns().saturating_sub(self.t0_ns)
    }

    /// Stop, record under `name` in `reg`, and return the duration.
    pub fn stop_into(self, reg: &mut Registry, name: &str) -> u64 {
        let ns = self.elapsed_ns();
        reg.record_ns(name, ns);
        ns
    }
}

// ---------------------------------------------------------------------------
// Process-wide bench registry

// The bench harness (`bench_util::bench`) records every measurement
// section here so end-of-run `BENCH_*.json` emission sees one registry
// regardless of which helper produced the samples. Cold path only.
static BENCH_REG: Mutex<Registry> = Mutex::new(Registry::new());

/// Run `f` against the process-wide bench registry.
pub fn with_bench_registry<R>(f: impl FnOnce(&mut Registry) -> R) -> R {
    let mut reg = BENCH_REG.lock().unwrap_or_else(|p| p.into_inner());
    f(&mut reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_span_nesting_with_fake_clock() {
        // outer strictly contains inner: outer = inner + 40ns of its own
        let clock = FakeClock::new();
        let mut reg = Registry::new();
        let outer = ScopedSpan::start(&clock);
        clock.advance(15);
        let inner = ScopedSpan::start(&clock);
        clock.advance(100);
        let inner_ns = inner.stop_into(&mut reg, "inner");
        clock.advance(25);
        let outer_ns = outer.stop_into(&mut reg, "outer");
        assert_eq!(inner_ns, 100);
        assert_eq!(outer_ns, 140);
        assert_eq!(reg.span("inner").unwrap().total_ns, 100);
        assert_eq!(reg.span("outer").unwrap().total_ns, 140);
        assert!(reg.span("outer").unwrap().total_ns
                    >= reg.span("inner").unwrap().total_ns);
    }

    #[test]
    fn fake_clock_drives_min_mean_max() {
        let clock = FakeClock::new();
        let mut reg = Registry::new();
        for ns in [40u64, 10, 30] {
            let s = ScopedSpan::start(&clock);
            clock.advance(ns);
            s.stop_into(&mut reg, "phase");
        }
        let s = reg.span("phase").unwrap();
        assert_eq!((s.count, s.min_ns, s.max_ns, s.total_ns),
                   (3, 10, 40, 80));
        assert!((s.mean_ns() - 80.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn worker_fold_is_worker_count_independent() {
        // The same 6 worker durations folded as 1, 2, or 3 "workers"
        // yield one aggregate: step-boundary merges cannot depend on
        // how many threads produced the samples.
        let durations = [7u64, 3, 9, 1, 5, 5];
        let fold = |parts: &[&[u64]]| {
            let mut reg = Registry::new();
            for part in parts {
                let mut partial = SpanStats::new();
                for &ns in *part {
                    partial.record(ns);
                }
                reg.merge_span("opt_worker", &partial);
            }
            *reg.span("opt_worker").unwrap()
        };
        let one = fold(&[&durations]);
        let two = fold(&[&durations[..3], &durations[3..]]);
        let three =
            fold(&[&durations[..2], &durations[2..4], &durations[4..]]);
        assert_eq!(one, two);
        assert_eq!(one, three);
    }

    #[test]
    fn thread_cells_fold_under_canonical_names() {
        let _g = enable();
        reset_thread();
        record_ns(Probe::CommHopReduce, 500);
        record_ns(Probe::CommHopReduce, 700);
        count(Counter::CommWireBytes, 4096);
        gauge(Gauge::CommBufferBytes, 1 << 16);
        let mut reg = Registry::new();
        thread_snapshot_into(&mut reg);
        let s = reg.span(Probe::CommHopReduce.name()).unwrap();
        assert_eq!((s.count, s.total_ns, s.min_ns, s.max_ns),
                   (2, 1200, 500, 700));
        assert!(reg.counter(Counter::CommWireBytes.name()).unwrap()
                    >= 4096);
        assert_eq!(reg.gauge_stats(Gauge::CommBufferBytes.name())
                       .unwrap().peak,
                   1 << 16);
        // untouched probes must not appear
        assert!(reg.span(Probe::Eval.name()).is_none());
        reset_thread();
    }

    #[test]
    fn gauges_keep_high_water_marks_per_thread() {
        let _g = enable();
        reset_thread();
        gauge(Gauge::OptStateBytes, 100);
        gauge(Gauge::OptStateBytes, 2_000);
        gauge(Gauge::OptStateBytes, 50);
        let s = thread_gauge(Gauge::OptStateBytes);
        assert_eq!(s.last, 50);
        assert_eq!(s.peak, 2_000);
        reset_thread();
    }

    #[test]
    fn step_deltas_come_from_snapshot_subtraction() {
        let _g = enable();
        reset_thread();
        record_ns(Probe::Grad, 2_000_000); // 2 ms of "previous steps"
        let before = thread_totals();
        record_ns(Probe::Grad, 3_000_000);
        record_ns(Probe::OptStep, 1_000_000);
        let after = thread_totals();
        let grad_ms = after.ms_since(&before, &[Probe::Grad]);
        let both_ms =
            after.ms_since(&before, &[Probe::Grad, Probe::OptStep]);
        assert!((grad_ms - 3.0).abs() < 1e-12);
        assert!((both_ms - 4.0).abs() < 1e-12);
        assert_eq!(after.spans(Probe::Grad) - before.spans(Probe::Grad), 1);
        reset_thread();
    }

    #[test]
    fn enabled_hot_path_is_allocation_free() {
        let _g = enable();
        reset_thread();
        // warm: first clock read anchors the OnceLock origin
        for _ in 0..8 {
            let _s = span(Probe::OptStep);
        }
        let before = crate::alloc_count::thread_allocs();
        for i in 0..100u64 {
            let _s = span(Probe::OptStep);
            count(Counter::CommWireBytes, 64);
            gauge(Gauge::OptStateBytes, i);
            record_ns(Probe::OptWorker, i);
        }
        let _t = thread_totals();
        assert_eq!(crate::alloc_count::thread_allocs(), before,
                   "telemetry hot path must never allocate");
        reset_thread();
    }

    #[test]
    fn disabled_spans_record_nothing() {
        reset_thread();
        let was_disabled = !enabled();
        let before = thread_totals();
        {
            let _s = span(Probe::Eval);
            count(Counter::CommExchanges, 1);
        }
        let after = thread_totals();
        // Another test's Enabled guard may overlap on the global flag;
        // only assert the no-op property when we observed it disabled
        // across the whole window.
        if was_disabled && !enabled() {
            assert_eq!(after.ns(Probe::Eval), before.ns(Probe::Eval));
            assert_eq!(after.counter(Counter::CommExchanges),
                       before.counter(Counter::CommExchanges));
        }
    }
}
