//! Per-event trace timeline: lock-free per-thread bounded ring buffers
//! recording one entry per completed [`Probe`] span (Chrome-trace `"X"`
//! complete events carrying the begin timestamp and duration) plus
//! instant entries for [`Counter`] / [`Gauge`] updates, tagged with a
//! thread lane and an optional comm rank — drained cold-side into a
//! Chrome-trace/Perfetto JSON document (DESIGN.md §17).
//!
//! Hot-path contract (the same determinism bargain as the rest of
//! `telemetry`): recording reads the monotonic clock and stores integer
//! words into a preallocated atomic ring — it never touches f32
//! training arithmetic, RNG state, or gradient buffers, so trajectories
//! are bitwise identical with tracing on or off. With tracing **off**
//! every entry point is a single relaxed load and an early return: zero
//! allocation, zero clock reads. With tracing **on** allocation is
//! *bounded*: one ring of [`RING_CAPACITY`] fixed-size entries per
//! participating thread, allocated on that thread's first traced event
//! and reused for the lifetime of the process.
//!
//! Drop policy: a ring that fills between drains **drops newest** —
//! the entry is discarded and a per-ring drop counter increments. The
//! drained document reports the total in `dropped_events`, so a
//! truncated timeline is visible rather than silently wrapped (a
//! wrap-around policy would tear in-progress entries under concurrent
//! drains; drop-newest keeps every exported entry internally
//! consistent). Drains happen at step boundaries, when all worker
//! scopes have joined and the persistent comm-hop worker is parked, so
//! in steady state the ring never fills at the default capacity.
//!
//! Lanes: every participating thread registers once and receives a
//! distinct lane id (the Chrome `tid`); the coordinator additionally
//! emits events on *synthetic* worker lanes ([`worker_lane`]) for the
//! scoped `ParallelStep` workers, whose own thread-locals die inside
//! the step — their begin/duration pairs are measured into preallocated
//! slots and replayed by the owner, so worker imbalance is visible as
//! parallel lanes without touching scoped-thread TLS after the join.

use super::{clock, Counter, Gauge, Probe};
use crate::json::Json;
use std::cell::{Cell, OnceCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Entries a per-thread ring holds between drains (fixed at first use;
/// beyond it the ring drops newest and counts the drops).
pub const RING_CAPACITY: usize = 16 * 1024;

/// Words per packed entry: `[ts_ns, dur_or_value, kind|id|rank|lane]`.
const WORDS: usize = 3;

/// `rank` field sentinel: event not attributed to a comm rank.
pub const NO_RANK: u32 = 0xFFFF;

/// Synthetic-lane namespace bit: lanes the owner replays on behalf of
/// scoped workers, disjoint from registered thread lanes by the high
/// bit.
const SYNTH_LANE: u32 = 0x8000_0000;

/// The synthetic lane id for sharded-step worker `wid` (rendered as
/// `opt_worker/<wid>` in the exported trace).
pub fn worker_lane(wid: usize) -> u32 {
    SYNTH_LANE | (wid as u32 & 0x7FFF_FFFF)
}

const KIND_SPAN: u64 = 0;
const KIND_COUNTER: u64 = 1;
const KIND_GAUGE: u64 = 2;

#[inline]
fn pack_tag(kind: u64, id: u64, rank: u32, lane: u32) -> u64 {
    (kind << 60) | ((id & 0xFF) << 48) | (((rank & 0xFFFF) as u64) << 32)
        | lane as u64
}

// ---------------------------------------------------------------------------
// Enablement (refcounted, like telemetry::ENABLED)

static TRACING: AtomicUsize = AtomicUsize::new(0);

/// True while at least one [`TracingGuard`] is alive.
#[inline]
pub fn tracing() -> bool {
    TRACING.load(Ordering::Relaxed) > 0
}

/// RAII tracing guard — trace entries record while it lives.
#[derive(Debug)]
pub struct TracingGuard {
    _priv: (),
}

/// Turn per-event tracing on until the returned guard drops. Guards
/// nest. Tracing is independent of (but only useful together with)
/// `telemetry::enable`, which gates the spans that feed it.
#[must_use = "tracing stays enabled only while the guard lives"]
pub fn enable_tracing() -> TracingGuard {
    TRACING.fetch_add(1, Ordering::Relaxed);
    TracingGuard { _priv: () }
}

impl Drop for TracingGuard {
    fn drop(&mut self) {
        TRACING.fetch_sub(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Per-thread rings

struct Ring {
    lane: u32,
    label: Mutex<String>,
    /// `WORDS * RING_CAPACITY` packed words; the owning thread stores
    /// relaxed then publishes via `len` (release), the drainer loads
    /// `len` (acquire) then reads the words — a bounded SPSC handoff.
    words: Box<[AtomicU64]>,
    len: AtomicUsize,
    dropped: AtomicU64,
}

impl Ring {
    fn new(lane: u32, label: String) -> Self {
        let words = (0..WORDS * RING_CAPACITY)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring {
            lane,
            label: Mutex::new(label),
            words,
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    #[inline]
    fn push(&self, ts_ns: u64, dur_or_value: u64, tag: u64) {
        let i = self.len.load(Ordering::Relaxed);
        if i >= RING_CAPACITY {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.words[WORDS * i].store(ts_ns, Ordering::Relaxed);
        self.words[WORDS * i + 1].store(dur_or_value, Ordering::Relaxed);
        self.words[WORDS * i + 2].store(tag, Ordering::Relaxed);
        self.len.store(i + 1, Ordering::Release);
    }
}

/// Registered rings, one per participating thread. Pushed once per
/// thread (cold); the drainer walks the list at step boundaries.
static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_RING: OnceCell<Arc<Ring>> = const { OnceCell::new() };
    static THREAD_RANK: Cell<u32> = const { Cell::new(NO_RANK) };
    static THREAD_LABEL: Cell<&'static str> = const { Cell::new("lane") };
}

fn with_ring(f: impl FnOnce(&Ring)) {
    let _ = THREAD_RING.try_with(|cell| {
        let ring = cell.get_or_init(|| {
            let lane = NEXT_LANE.fetch_add(1, Ordering::Relaxed) as u32
                & !SYNTH_LANE;
            let label = THREAD_LABEL
                .try_with(Cell::get)
                .unwrap_or("lane")
                .to_string();
            let ring = Arc::new(Ring::new(lane, label));
            RINGS
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(Arc::clone(&ring));
            ring
        });
        f(ring);
    });
}

/// Name this thread's trace lane (e.g. `"coordinator"`, `"comm-hop"`).
/// Takes effect immediately whether or not the ring exists yet; cold
/// path (once per thread).
pub fn set_thread_label(label: &'static str) {
    let _ = THREAD_LABEL.try_with(|c| c.set(label));
    let _ = THREAD_RING.try_with(|cell| {
        if let Some(ring) = cell.get() {
            *ring.label.lock().unwrap_or_else(|p| p.into_inner()) =
                label.to_string();
        }
    });
}

/// Attribute subsequent events on this thread to comm `rank` (the
/// engine brackets per-rank staging loops with this). [`NO_RANK`]
/// clears the attribution.
#[inline]
pub fn set_rank(rank: u32) {
    let _ = THREAD_RANK.try_with(|c| c.set(rank));
}

/// Clear the comm-rank attribution on this thread.
#[inline]
pub fn clear_rank() {
    set_rank(NO_RANK);
}

// ---------------------------------------------------------------------------
// Recording

/// Record a completed span: begin timestamp `t0_ns`, duration `dur_ns`,
/// on this thread's lane. No-op (one relaxed load) while tracing is off.
#[inline]
pub fn complete(probe: Probe, t0_ns: u64, dur_ns: u64) {
    if !tracing() {
        return;
    }
    let rank = THREAD_RANK.try_with(Cell::get).unwrap_or(NO_RANK);
    with_ring(|r| {
        r.push(t0_ns, dur_ns,
               pack_tag(KIND_SPAN, probe as u64, rank, r.lane));
    });
}

/// Record a completed span on an explicit (synthetic) lane — the owner
/// replaying a scoped worker's measured `(begin, duration)` slot onto
/// [`worker_lane`].
#[inline]
pub fn complete_on_lane(probe: Probe, lane: u32, t0_ns: u64, dur_ns: u64) {
    if !tracing() {
        return;
    }
    with_ring(|r| {
        r.push(t0_ns, dur_ns,
               pack_tag(KIND_SPAN, probe as u64, NO_RANK, lane));
    });
}

/// Record an instant event for a counter increment (`value` = the
/// added amount), timestamped now.
#[inline]
pub fn instant_counter(counter: Counter, value: u64) {
    if !tracing() {
        return;
    }
    let rank = THREAD_RANK.try_with(Cell::get).unwrap_or(NO_RANK);
    with_ring(|r| {
        r.push(clock::now_ns(), value,
               pack_tag(KIND_COUNTER, counter as u64, rank, r.lane));
    });
}

/// Record an instant event for a gauge sample (`value` = the sampled
/// level), timestamped now.
#[inline]
pub fn instant_gauge(gauge: Gauge, value: u64) {
    if !tracing() {
        return;
    }
    let rank = THREAD_RANK.try_with(Cell::get).unwrap_or(NO_RANK);
    with_ring(|r| {
        r.push(clock::now_ns(), value,
               pack_tag(KIND_GAUGE, gauge as u64, rank, r.lane));
    });
}

// ---------------------------------------------------------------------------
// Draining (cold side)

/// One decoded trace entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    /// Canonical probe/counter/gauge name.
    pub name: &'static str,
    /// `"span"`, `"counter"`, or `"gauge"`.
    pub kind: &'static str,
    /// Begin timestamp (spans) or sample timestamp (instants), ns.
    pub ts_ns: u64,
    /// Span duration in ns; 0 for instants.
    pub dur_ns: u64,
    /// Counter delta / gauge level; 0 for spans.
    pub value: u64,
    /// Lane (Chrome `tid`): a registered thread or a synthetic worker
    /// lane.
    pub lane: u32,
    /// Comm rank the event is attributed to, if any.
    pub rank: Option<u32>,
}

fn decode(ts: u64, dv: u64, tag: u64) -> Option<TraceRecord> {
    let kind = tag >> 60;
    let id = ((tag >> 48) & 0xFF) as usize;
    let rank = ((tag >> 32) & 0xFFFF) as u32;
    let lane = (tag & 0xFFFF_FFFF) as u32;
    let rank = if rank == NO_RANK { None } else { Some(rank) };
    let (name, kind, dur, value) = match kind {
        KIND_SPAN => {
            (Probe::ALL.get(id)?.name(), "span", dv, 0)
        }
        KIND_COUNTER => {
            (Counter::ALL.get(id)?.name(), "counter", 0, dv)
        }
        KIND_GAUGE => {
            (Gauge::ALL.get(id)?.name(), "gauge", 0, dv)
        }
        _ => return None,
    };
    Some(TraceRecord { name, kind, ts_ns: ts, dur_ns: dur, value, lane, rank })
}

/// Collected timeline: decoded records, lane labels, and the total
/// number of entries dropped by full rings.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    /// Decoded entries in drain order (sort before export).
    pub records: Vec<TraceRecord>,
    /// Registered lane labels (synthetic worker lanes are named at
    /// export time).
    pub lanes: BTreeMap<u32, String>,
    /// Entries dropped because a ring filled between drains.
    pub dropped: u64,
}

impl Timeline {
    /// Drain every registered ring into this timeline, resetting the
    /// rings. Call at quiescent points only (step boundaries): the
    /// reset races benignly with a concurrent writer — an entry may be
    /// lost, never torn.
    pub fn drain(&mut self) {
        let rings: Vec<Arc<Ring>> = RINGS
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone();
        for ring in rings {
            let n = ring.len.load(Ordering::Acquire).min(RING_CAPACITY);
            for i in 0..n {
                let ts = ring.words[WORDS * i].load(Ordering::Relaxed);
                let dv = ring.words[WORDS * i + 1].load(Ordering::Relaxed);
                let tag = ring.words[WORDS * i + 2].load(Ordering::Relaxed);
                if let Some(rec) = decode(ts, dv, tag) {
                    self.records.push(rec);
                }
            }
            ring.len.store(0, Ordering::Release);
            self.dropped += ring.dropped.swap(0, Ordering::Relaxed);
            self.lanes.insert(
                ring.lane,
                ring.label.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            );
        }
    }

    /// Export as a Chrome-trace/Perfetto JSON document (schema
    /// [`TRACE_SCHEMA`]): `"M"` thread-name metadata per lane, `"X"`
    /// complete events for spans (`ts`/`dur` in microseconds), `"i"`
    /// instants for counter/gauge updates. Events are ordered by
    /// `(ts, lane, -dur)` so enclosing spans precede their children.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        let mut lanes = self.lanes.clone();
        for rec in &self.records {
            if rec.lane & SYNTH_LANE != 0 {
                lanes
                    .entry(rec.lane)
                    .or_insert_with(|| format!("opt_worker/{}",
                                               rec.lane & !SYNTH_LANE));
            }
        }
        for (lane, label) in &lanes {
            let mut args = BTreeMap::new();
            args.insert("name".into(), Json::String(label.clone()));
            let mut m = BTreeMap::new();
            m.insert("ph".into(), Json::String("M".into()));
            m.insert("name".into(), Json::String("thread_name".into()));
            m.insert("pid".into(), Json::Number(0.0));
            m.insert("tid".into(), Json::Number(*lane as f64));
            m.insert("args".into(), Json::Object(args));
            events.push(Json::Object(m));
        }
        let mut order: Vec<usize> = (0..self.records.len()).collect();
        order.sort_by(|&a, &b| {
            let (ra, rb) = (&self.records[a], &self.records[b]);
            ra.ts_ns
                .cmp(&rb.ts_ns)
                .then(ra.lane.cmp(&rb.lane))
                .then(rb.dur_ns.cmp(&ra.dur_ns))
                .then(a.cmp(&b))
        });
        for i in order {
            let rec = &self.records[i];
            let mut e = BTreeMap::new();
            e.insert("name".into(), Json::String(rec.name.to_string()));
            e.insert("pid".into(), Json::Number(0.0));
            e.insert("tid".into(), Json::Number(rec.lane as f64));
            e.insert("ts".into(), Json::Number(rec.ts_ns as f64 / 1e3));
            let mut args = BTreeMap::new();
            if let Some(r) = rec.rank {
                args.insert("rank".into(), Json::Number(r as f64));
            }
            match rec.kind {
                "span" => {
                    e.insert("ph".into(), Json::String("X".into()));
                    e.insert("cat".into(), Json::String("span".into()));
                    e.insert("dur".into(),
                             Json::Number(rec.dur_ns as f64 / 1e3));
                }
                kind => {
                    e.insert("ph".into(), Json::String("i".into()));
                    e.insert("s".into(), Json::String("t".into()));
                    e.insert("cat".into(), Json::String(kind.to_string()));
                    args.insert("value".into(),
                                Json::Number(rec.value as f64));
                }
            }
            if !args.is_empty() {
                e.insert("args".into(), Json::Object(args));
            }
            events.push(Json::Object(e));
        }
        let mut doc = BTreeMap::new();
        doc.insert("schema".into(), Json::String(TRACE_SCHEMA.to_string()));
        doc.insert("displayTimeUnit".into(), Json::String("ns".into()));
        doc.insert("dropped_events".into(),
                   Json::Number(self.dropped as f64));
        doc.insert("traceEvents".into(), Json::Array(events));
        Json::Object(doc)
    }
}

/// Schema tag stamped into every exported trace document; the checker
/// ([`validate_trace_doc`], `sm3-train report --check`) rejects any
/// other tag.
pub const TRACE_SCHEMA: &str = "sm3-trace-v1";

fn num(e: &Json, key: &str, ctx: &str) -> Result<f64, String> {
    e.get(key)
        .and_then(Json::as_f64)
        .filter(|n| n.is_finite())
        .ok_or_else(|| format!("{ctx}: missing numeric field `{key}`"))
}

/// Validate a parsed trace document: schema tag, well-formed events
/// (every `"X"` carries non-negative `ts`/`dur`, every `"i"` a
/// timestamp and a value), and the per-lane nesting invariant — on one
/// lane, complete events are either disjoint or properly nested (a
/// laminar family), which is what makes the timeline renderable as
/// stacked spans.
pub fn validate_trace_doc(doc: &Json) -> Result<(), String> {
    let obj = doc.as_object().ok_or("trace is not a JSON object")?;
    match obj.get("schema").and_then(Json::as_str) {
        Some(s) if s == TRACE_SCHEMA => {}
        Some(s) => return Err(format!("unknown trace schema tag `{s}`")),
        None => return Err("missing string field `schema`".into()),
    }
    if obj
        .get("dropped_events")
        .and_then(Json::as_f64)
        .filter(|n| n.is_finite() && *n >= 0.0)
        .is_none()
    {
        return Err("missing numeric field `dropped_events`".into());
    }
    let events = match obj.get("traceEvents") {
        Some(Json::Array(a)) => a,
        _ => return Err("missing array field `traceEvents`".into()),
    };
    // per-lane X intervals for the nesting check
    let mut spans: BTreeMap<u64, Vec<(f64, f64)>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let ctx = format!("event #{i}");
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{ctx}: missing string field `ph`"))?;
        if e.get("name").and_then(Json::as_str).is_none() {
            return Err(format!("{ctx}: missing string field `name`"));
        }
        match ph {
            "M" => continue,
            "X" => {
                let tid = num(e, "tid", &ctx)?;
                let ts = num(e, "ts", &ctx)?;
                let dur = num(e, "dur", &ctx)?;
                if ts < 0.0 || dur < 0.0 {
                    return Err(format!(
                        "{ctx}: negative ts={ts} or dur={dur}"));
                }
                spans.entry(tid as u64).or_default().push((ts, ts + dur));
            }
            "i" => {
                let ts = num(e, "ts", &ctx)?;
                if ts < 0.0 {
                    return Err(format!("{ctx}: negative ts={ts}"));
                }
                if e.get("args")
                    .and_then(|a| a.get("value"))
                    .and_then(Json::as_f64)
                    .is_none()
                {
                    return Err(format!(
                        "{ctx}: instant without `args.value`"));
                }
            }
            other => {
                return Err(format!("{ctx}: unknown phase `{other}`"));
            }
        }
    }
    for (lane, mut iv) in spans {
        // sort by start asc, end desc: an enclosing span sorts first
        iv.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                .then(b.1.partial_cmp(&a.1).unwrap())
        });
        let mut stack: Vec<f64> = Vec::new();
        for (start, end) in iv {
            while let Some(&top) = stack.last() {
                if top <= start {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = stack.last() {
                if end > top {
                    return Err(format!(
                        "lane {lane}: span [{start}, {end}] straddles \
                         enclosing span ending at {top} — intervals must \
                         nest or be disjoint"
                    ));
                }
            }
            stack.push(end);
        }
    }
    Ok(())
}

/// Measured hop-vs-stage concurrency from a parsed trace document: the
/// fraction of total ring-hop span time during which a staging span
/// (`comm/pack` / `comm/feedback`) was simultaneously open on a
/// *different* lane — the overlap-efficiency figure `sm3-train report`
/// prints (1.0 = every hop fully hidden staging, 0.0 = no overlap).
/// Returns `None` when the trace has no hop spans.
pub fn overlap_efficiency(doc: &Json) -> Option<f64> {
    let events = match doc.get("traceEvents") {
        Some(Json::Array(a)) => a,
        _ => return None,
    };
    let mut hops: Vec<(f64, f64, u64)> = Vec::new();
    let mut stages: Vec<(f64, f64, u64)> = Vec::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let name = e.get("name").and_then(Json::as_str)?;
        let ts = e.get("ts").and_then(Json::as_f64)?;
        let dur = e.get("dur").and_then(Json::as_f64)?;
        let tid = e.get("tid").and_then(Json::as_f64)? as u64;
        if name.starts_with("comm/hop_") {
            hops.push((ts, ts + dur, tid));
        } else if name == "comm/pack" || name == "comm/feedback" {
            stages.push((ts, ts + dur, tid));
        }
    }
    if hops.is_empty() {
        return None;
    }
    let total: f64 = hops.iter().map(|&(s, e, _)| e - s).sum();
    if total <= 0.0 {
        return Some(0.0);
    }
    let mut covered = 0.0;
    for &(hs, he, hl) in &hops {
        // merge the cross-lane stage intervals clipped to this hop
        let mut clips: Vec<(f64, f64)> = stages
            .iter()
            .filter(|&&(_, _, sl)| sl != hl)
            .map(|&(ss, se, _)| (ss.max(hs), se.min(he)))
            .filter(|&(s, e)| e > s)
            .collect();
        clips.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut cursor = hs;
        for (s, e) in clips {
            let s = s.max(cursor);
            if e > s {
                covered += e - s;
                cursor = e;
            }
        }
    }
    Some((covered / total).clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Serialize ring-global tests: rings and the TRACING flag are
    // process-wide, so concurrent harness threads would cross-drain.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_tracing_records_nothing_and_never_allocates() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!tracing());
        let before = crate::alloc_count::thread_allocs();
        for i in 0..64 {
            complete(Probe::OptStep, i, 10);
            instant_counter(Counter::CommWireBytes, 64);
            instant_gauge(Gauge::PoolBytes, 1 << 20);
        }
        assert_eq!(crate::alloc_count::thread_allocs(), before,
                   "tracing-off entry points must not allocate");
        let mut tl = Timeline::default();
        tl.drain();
        assert!(tl.records.is_empty());
    }

    #[test]
    fn enabled_tracing_allocates_once_then_stays_flat() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let _g = enable_tracing();
        // first event allocates the ring (bounded, once per thread)
        complete(Probe::OptStep, 0, 5);
        let before = crate::alloc_count::thread_allocs();
        for i in 1..256u64 {
            complete(Probe::OptStep, i * 10, 5);
            instant_counter(Counter::CommWireBytes, 64);
        }
        assert_eq!(crate::alloc_count::thread_allocs(), before,
                   "steady-state tracing must reuse the ring");
        let mut tl = Timeline::default();
        tl.drain();
        assert!(tl.records.len() >= 511);
    }

    #[test]
    fn records_round_trip_with_lane_rank_and_kind() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        {
            let mut tl = Timeline::default();
            tl.drain(); // flush leftovers from other tests
        }
        let _g = enable_tracing();
        set_thread_label("test-lane");
        set_rank(3);
        complete(Probe::CommPack, 1000, 500);
        clear_rank();
        complete_on_lane(Probe::OptWorker, worker_lane(2), 2000, 800);
        instant_gauge(Gauge::PoolBytes, 4096);
        let mut tl = Timeline::default();
        tl.drain();
        let pack = tl
            .records
            .iter()
            .find(|r| r.name == "comm/pack")
            .expect("pack span drained");
        assert_eq!((pack.ts_ns, pack.dur_ns, pack.rank),
                   (1000, 500, Some(3)));
        let w = tl
            .records
            .iter()
            .find(|r| r.name == "opt_worker")
            .expect("worker span drained");
        assert_eq!(w.lane, worker_lane(2));
        assert_eq!(w.rank, None);
        let g = tl
            .records
            .iter()
            .find(|r| r.name == "mem/pool_bytes")
            .expect("gauge instant drained");
        assert_eq!((g.kind, g.value), ("gauge", 4096));
        // the drain reset the ring
        let mut again = Timeline::default();
        again.drain();
        assert!(again.records.iter().all(|r| r.name != "comm/pack"));
    }

    #[test]
    fn full_ring_drops_newest_and_reports_the_count() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        {
            let mut tl = Timeline::default();
            tl.drain();
        }
        let _g = enable_tracing();
        for i in 0..(RING_CAPACITY as u64 + 100) {
            complete(Probe::Grad, i, 1);
        }
        let mut tl = Timeline::default();
        tl.drain();
        let grads =
            tl.records.iter().filter(|r| r.name == "grad").count();
        assert_eq!(grads, RING_CAPACITY, "ring holds exactly its capacity");
        assert_eq!(tl.dropped, 100, "overflow must be counted, not wrapped");
        // earliest events survive (drop-newest, not drop-oldest)
        assert!(tl.records.iter().any(|r| r.name == "grad" && r.ts_ns == 0));
    }

    #[test]
    fn chrome_export_validates_and_orders_nested_spans() {
        let tl = Timeline {
            records: vec![
                TraceRecord { name: "opt_step", kind: "span", ts_ns: 1000,
                              dur_ns: 9000, value: 0, lane: 0, rank: None },
                TraceRecord { name: "opt_worker", kind: "span", ts_ns: 2000,
                              dur_ns: 3000, value: 0, lane: 0, rank: None },
                TraceRecord { name: "comm/wire_bytes", kind: "counter",
                              ts_ns: 4000, dur_ns: 0, value: 256, lane: 1,
                              rank: Some(2) },
            ],
            lanes: BTreeMap::from([(0, "coordinator".into()),
                                   (1, "comm-hop".into())]),
            dropped: 0,
        };
        let doc = tl.to_chrome_json();
        validate_trace_doc(&doc).unwrap();
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        validate_trace_doc(&parsed).unwrap();
        let events = match parsed.get("traceEvents") {
            Some(Json::Array(a)) => a.clone(),
            _ => panic!("traceEvents missing"),
        };
        // metadata first, then X events ordered ts asc with the
        // enclosing span before its child
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        assert_eq!(xs[0].get("name").and_then(Json::as_str),
                   Some("opt_step"));
        assert_eq!(xs[0].get("ts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(xs[0].get("dur").and_then(Json::as_f64), Some(9.0));
        // instant carries its value and rank
        let inst = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .unwrap();
        let args = inst.get("args").unwrap();
        assert_eq!(args.get("value").and_then(Json::as_f64), Some(256.0));
        assert_eq!(args.get("rank").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn fake_clock_trace_round_trips_through_chrome_json() {
        use crate::telemetry::{Clock, FakeClock};
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        {
            let mut tl = Timeline::default();
            tl.drain(); // flush leftovers from other tests
        }
        let _g = enable_tracing();
        set_thread_label("fake-clock");
        // a deterministic schedule: outer opt_step [1000, 10000) strictly
        // containing an opt_worker replay [2000, 5000) on worker lane 1,
        // a rank-tagged pack instant at t=3000, and a later grad span
        // [12000, 15000) — all stamped from a FakeClock, so every
        // exported ts/dur is exact, not wall-clock-approximate
        let clock = FakeClock::new();
        clock.set(1_000);
        let t_outer = clock.now_ns();
        clock.advance(1_000);
        let t_inner = clock.now_ns();
        clock.advance(3_000);
        complete_on_lane(Probe::OptWorker, worker_lane(1), t_inner,
                         clock.now_ns() - t_inner);
        clock.advance(5_000);
        complete(Probe::OptStep, t_outer, clock.now_ns() - t_outer);
        set_rank(2);
        instant_counter(Counter::CommWireBytes, 640);
        clear_rank();
        clock.advance(2_000);
        let t_grad = clock.now_ns();
        clock.advance(3_000);
        complete(Probe::Grad, t_grad, clock.now_ns() - t_grad);
        let mut tl = Timeline::default();
        tl.drain();
        // round-trip: export → serialize → re-parse with the in-crate
        // parser → re-validate the parsed document
        let doc = tl.to_chrome_json();
        validate_trace_doc(&doc).unwrap();
        let parsed = Json::parse(&doc.to_string()).unwrap();
        validate_trace_doc(&parsed).unwrap();
        let events = parsed.get("traceEvents").unwrap();
        let events = match events {
            Json::Array(a) => a,
            _ => panic!("traceEvents must be an array"),
        };
        // lane invariant: the labeled thread and the synthetic worker
        // lane each carry a thread_name metadata event
        let lane_names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .filter_map(|e| e.get("args")?.get("name")?.as_str())
            .collect();
        assert!(lane_names.contains(&"fake-clock"), "{lane_names:?}");
        assert!(lane_names.iter().any(|n| n.contains("worker")),
                "worker lane must be labeled: {lane_names:?}");
        // ordering invariant: X events sorted by ts, exact fake-clock
        // microseconds
        let xs: Vec<(&str, f64, f64, f64)> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| (e.get("name").and_then(Json::as_str).unwrap(),
                      e.get("ts").and_then(Json::as_f64).unwrap(),
                      e.get("dur").and_then(Json::as_f64).unwrap(),
                      e.get("tid").and_then(Json::as_f64).unwrap()))
            .collect();
        assert!(xs.windows(2).all(|w| w[0].1 <= w[1].1),
                "X events must be ts-ascending: {xs:?}");
        let step = xs.iter().find(|x| x.0 == "opt_step").unwrap();
        let worker = xs.iter().find(|x| x.0 == "opt_worker").unwrap();
        let grad = xs.iter().find(|x| x.0 == "grad").unwrap();
        assert_eq!((step.1, step.2), (1.0, 9.0));
        assert_eq!((worker.1, worker.2), (2.0, 3.0));
        assert_eq!((grad.1, grad.2), (12.0, 15.0 - 12.0));
        // nesting invariant: the worker replay lies strictly inside the
        // enclosing opt_step, on its own (different) lane
        assert!(step.1 <= worker.1
                && worker.1 + worker.2 <= step.1 + step.2);
        assert_ne!(step.3, worker.3, "replayed worker spans get their \
                                      own synthetic lane");
        // the rank tag survives the round trip on the instant
        let inst = events
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .unwrap();
        assert_eq!(inst.get("args").unwrap().get("rank")
                       .and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn validator_rejects_straddling_spans_and_bad_schema() {
        // straddling intervals on one lane: [0, 10) and [5, 15)
        let tl = Timeline {
            records: vec![
                TraceRecord { name: "grad", kind: "span", ts_ns: 0,
                              dur_ns: 10_000, value: 0, lane: 0,
                              rank: None },
                TraceRecord { name: "opt_step", kind: "span", ts_ns: 5_000,
                              dur_ns: 10_000, value: 0, lane: 0,
                              rank: None },
            ],
            lanes: BTreeMap::new(),
            dropped: 0,
        };
        let err = validate_trace_doc(&tl.to_chrome_json()).unwrap_err();
        assert!(err.contains("straddles"), "{err}");
        // same intervals on different lanes are fine
        let tl2 = Timeline {
            records: vec![
                TraceRecord { name: "grad", kind: "span", ts_ns: 0,
                              dur_ns: 10_000, value: 0, lane: 0,
                              rank: None },
                TraceRecord { name: "opt_step", kind: "span", ts_ns: 5_000,
                              dur_ns: 10_000, value: 0, lane: 1,
                              rank: None },
            ],
            lanes: BTreeMap::new(),
            dropped: 0,
        };
        validate_trace_doc(&tl2.to_chrome_json()).unwrap();
        // schema tag is enforced
        let bad = Json::parse(
            r#"{"schema":"nope","dropped_events":0,"traceEvents":[]}"#)
            .unwrap();
        assert!(validate_trace_doc(&bad).is_err());
        assert!(validate_trace_doc(&Json::parse("[]").unwrap()).is_err());
    }

    #[test]
    fn overlap_efficiency_measures_cross_lane_concurrency() {
        // hop on lane 1 over [0, 100); staging on lane 0 covers
        // [20, 60) — 40% hidden. A same-lane stage must not count.
        let tl = Timeline {
            records: vec![
                TraceRecord { name: "comm/hop_reduce", kind: "span",
                              ts_ns: 0, dur_ns: 100_000, value: 0,
                              lane: 1, rank: None },
                TraceRecord { name: "comm/pack", kind: "span",
                              ts_ns: 20_000, dur_ns: 40_000, value: 0,
                              lane: 0, rank: None },
                TraceRecord { name: "comm/feedback", kind: "span",
                              ts_ns: 110_000, dur_ns: 40_000, value: 0,
                              lane: 1, rank: None },
            ],
            lanes: BTreeMap::new(),
            dropped: 0,
        };
        let doc = tl.to_chrome_json();
        let eff = overlap_efficiency(&doc).unwrap();
        assert!((eff - 0.4).abs() < 1e-9, "{eff}");
        // no hops → None
        let empty = Timeline::default().to_chrome_json();
        assert_eq!(overlap_efficiency(&empty), None);
    }
}
