//! Clock abstraction for the telemetry subsystem (DESIGN.md §14).
//!
//! Production spans read a process-wide monotonic clock; tests inject a
//! [`FakeClock`] so span semantics (nesting, min/max, totals) are
//! asserted against exact, deterministic timestamps instead of wall
//! time. Timestamps are `u64` nanoseconds since an arbitrary per-process
//! origin — only differences are meaningful.

use std::cell::Cell;
use std::sync::OnceLock;
use std::time::Instant;

/// A monotonic nanosecond source. Object-safe so instrumented code can
/// hold `&dyn Clock` and tests can swap in a [`FakeClock`].
pub trait Clock {
    /// Nanoseconds since this clock's origin. Must be monotone
    /// non-decreasing on a given clock instance.
    fn now_ns(&self) -> u64;
}

// Anchor for the process-wide monotonic clock. OnceLock stores the
// Instant inline, so initializing it on first use never allocates —
// required because spans fire inside allocation-free steady-state paths.
static ORIGIN: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the first telemetry clock read in this process.
/// The global monotonic source behind [`MonotonicClock`] and the
/// hot-path span API in [`crate::telemetry`].
#[inline]
pub fn now_ns() -> u64 {
    ORIGIN.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// The production clock: [`std::time::Instant`] against a process-wide
/// origin, shared by every span so timestamps are comparable across
/// threads.
#[derive(Clone, Copy, Debug, Default)]
pub struct MonotonicClock;

impl Clock for MonotonicClock {
    #[inline]
    fn now_ns(&self) -> u64 {
        now_ns()
    }
}

/// Deterministic test clock: time advances only when the test says so.
/// Deliberately `!Sync` (interior `Cell`) — a fake clock belongs to one
/// test thread; cross-thread tests use per-thread instances.
#[derive(Debug, Default)]
pub struct FakeClock {
    ns: Cell<u64>,
}

impl FakeClock {
    /// A fake clock starting at t = 0 ns.
    pub fn new() -> Self {
        FakeClock { ns: Cell::new(0) }
    }

    /// Jump to an absolute timestamp (must not go backwards in tests
    /// that assert monotonicity; the clock itself does not check).
    pub fn set(&self, ns: u64) {
        self.ns.set(ns);
    }

    /// Advance time by `ns` nanoseconds.
    pub fn advance(&self, ns: u64) {
        self.ns.set(self.ns.get() + ns);
    }
}

impl Clock for FakeClock {
    #[inline]
    fn now_ns(&self) -> u64 {
        self.ns.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_is_nondecreasing() {
        let c = MonotonicClock;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn fake_clock_advances_only_on_demand() {
        let c = FakeClock::new();
        assert_eq!(c.now_ns(), 0);
        assert_eq!(c.now_ns(), 0);
        c.advance(250);
        assert_eq!(c.now_ns(), 250);
        c.set(1_000);
        assert_eq!(c.now_ns(), 1_000);
    }
}
