//! Run-level aggregation: string-keyed per-phase stats, counters, and
//! gauges, plus the `BENCH_*.json` document builder and its schema
//! checker (DESIGN.md §14, EXPERIMENTS.md §Telemetry).
//!
//! The [`Registry`] is the *cold* side of telemetry: hot paths record
//! into fixed thread-local cells (`telemetry::span` / `count` /
//! `gauge`), and those cells are folded into a registry at step or run
//! boundaries. Benches also record into a registry directly through
//! `bench_util::bench`, so the per-phase CSV tables and the
//! `BENCH_*.json` trajectory are produced by one code path.

use crate::json::Json;
use std::collections::BTreeMap;

/// Aggregated timing stats for one named phase: count / total / min /
/// max (mean is derived). Merging is commutative and associative, so
/// per-worker partials folded in any grouping yield the same aggregate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanStats {
    /// Number of recorded spans.
    pub count: u64,
    /// Sum of span durations, nanoseconds.
    pub total_ns: u64,
    /// Shortest span, nanoseconds (`u64::MAX` while `count == 0`).
    pub min_ns: u64,
    /// Longest span, nanoseconds.
    pub max_ns: u64,
}

impl SpanStats {
    /// Empty stats (identity element for [`SpanStats::merge`]).
    pub const fn new() -> Self {
        SpanStats { count: 0, total_ns: 0, min_ns: u64::MAX, max_ns: 0 }
    }

    /// Fold one span duration in.
    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.total_ns += ns;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Fold another partial aggregate in (order-independent).
    pub fn merge(&mut self, other: &SpanStats) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.total_ns += other.total_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Mean span duration in nanoseconds (0.0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }

    /// `min_ns` as reported externally: 0 for an empty aggregate so the
    /// JSON export never leaks the `u64::MAX` sentinel.
    pub fn min_ns_or_zero(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }
}

impl Default for SpanStats {
    fn default() -> Self {
        SpanStats::new()
    }
}

/// A sampled quantity with a high-water mark: `last` is the most recent
/// sample, `peak` the maximum ever set.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GaugeStats {
    /// Most recent sample.
    pub last: u64,
    /// High-water mark across all samples.
    pub peak: u64,
}

impl GaugeStats {
    /// Record a sample, keeping the high-water mark.
    pub fn set(&mut self, v: u64) {
        self.last = v;
        self.peak = self.peak.max(v);
    }

    /// Fold another gauge in: the peak is the max of both, and `last`
    /// takes the other side's value (callers merge in a deterministic
    /// worker-index order, so `last` is well-defined).
    pub fn merge(&mut self, other: &GaugeStats) {
        self.last = other.last;
        self.peak = self.peak.max(other.peak);
    }

    /// Re-arm the high-water mark at the current sample: the next run's
    /// peak starts from its own live level.
    pub fn reset_peak(&mut self) {
        self.peak = self.last;
    }
}

/// String-keyed run aggregate: per-phase [`SpanStats`], monotone
/// counters, and [`GaugeStats`]. BTreeMap keys give deterministic
/// iteration and JSON field order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    spans: BTreeMap<String, SpanStats>,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, GaugeStats>,
}

impl Registry {
    /// An empty registry.
    pub const fn new() -> Self {
        Registry {
            spans: BTreeMap::new(),
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
        }
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.counters.is_empty()
            && self.gauges.is_empty()
    }

    /// Record one span duration under `name`.
    pub fn record_ns(&mut self, name: &str, ns: u64) {
        if let Some(s) = self.spans.get_mut(name) {
            s.record(ns);
        } else {
            let mut s = SpanStats::new();
            s.record(ns);
            self.spans.insert(name.to_string(), s);
        }
    }

    /// Fold a partial span aggregate (e.g. one thread's cells) under
    /// `name`.
    pub fn merge_span(&mut self, name: &str, stats: &SpanStats) {
        if let Some(s) = self.spans.get_mut(name) {
            s.merge(stats);
        } else {
            let mut s = SpanStats::new();
            s.merge(stats);
            self.spans.insert(name.to_string(), s);
        }
    }

    /// Add `n` to the counter `name`.
    pub fn add(&mut self, name: &str, n: u64) {
        if let Some(c) = self.counters.get_mut(name) {
            *c += n;
        } else {
            self.counters.insert(name.to_string(), n);
        }
    }

    /// Set gauge `name` to `v`, keeping its high-water mark.
    pub fn gauge(&mut self, name: &str, v: u64) {
        if let Some(g) = self.gauges.get_mut(name) {
            g.set(v);
        } else {
            let mut g = GaugeStats::default();
            g.set(v);
            self.gauges.insert(name.to_string(), g);
        }
    }

    /// Fold a gauge aggregate under `name`.
    pub fn merge_gauge(&mut self, name: &str, stats: &GaugeStats) {
        if let Some(g) = self.gauges.get_mut(name) {
            g.merge(stats);
        } else {
            self.gauges.insert(name.to_string(), *stats);
        }
    }

    /// Fold an entire registry in (used to combine per-worker or
    /// per-section partials; commutative for spans/counters, `last`
    /// of equal-named gauges takes `other`'s value).
    pub fn merge(&mut self, other: &Registry) {
        for (k, s) in &other.spans {
            self.merge_span(k, s);
        }
        for (k, n) in &other.counters {
            self.add(k, *n);
        }
        for (k, g) in &other.gauges {
            self.merge_gauge(k, g);
        }
    }

    /// Start a new run within the same process: re-arm every gauge's
    /// high-water mark at its current level ([`GaugeStats::reset_peak`]).
    /// One process driving multiple bench configs must call this
    /// between sections, or a later section's exported peaks
    /// (`mem/pool_bytes_peak`, `comm/inflight_buckets`) silently carry
    /// an earlier, larger config's high-water mark. Spans and counters
    /// are left to accumulate: they are cumulative trajectory totals,
    /// not per-run marks.
    pub fn reset_run(&mut self) {
        for g in self.gauges.values_mut() {
            g.reset_peak();
        }
    }

    /// Look up a phase aggregate.
    pub fn span(&self, name: &str) -> Option<&SpanStats> {
        self.spans.get(name)
    }

    /// Look up a counter.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    /// Look up a gauge.
    pub fn gauge_stats(&self, name: &str) -> Option<&GaugeStats> {
        self.gauges.get(name)
    }

    /// Iterate phases in name order.
    pub fn spans(&self) -> impl Iterator<Item = (&str, &SpanStats)> {
        self.spans.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Iterate counters in name order.
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate gauges in name order.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, &GaugeStats)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The registry as a JSON object `{spans, counters, gauges}` —
    /// the payload section of a `BENCH_*.json` document and of the
    /// end-of-run JSONL summary event.
    pub fn to_json(&self) -> Json {
        let mut spans = BTreeMap::new();
        for (name, s) in &self.spans {
            let mut o = BTreeMap::new();
            o.insert("count".into(), Json::Number(s.count as f64));
            o.insert("total_ns".into(), Json::Number(s.total_ns as f64));
            o.insert("min_ns".into(),
                     Json::Number(s.min_ns_or_zero() as f64));
            o.insert("max_ns".into(), Json::Number(s.max_ns as f64));
            o.insert("mean_ns".into(), Json::Number(s.mean_ns()));
            spans.insert(name.clone(), Json::Object(o));
        }
        let mut counters = BTreeMap::new();
        for (name, n) in &self.counters {
            counters.insert(name.clone(), Json::Number(*n as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, g) in &self.gauges {
            let mut o = BTreeMap::new();
            o.insert("last".into(), Json::Number(g.last as f64));
            o.insert("peak".into(), Json::Number(g.peak as f64));
            gauges.insert(name.clone(), Json::Object(o));
        }
        let mut doc = BTreeMap::new();
        doc.insert("spans".into(), Json::Object(spans));
        doc.insert("counters".into(), Json::Object(counters));
        doc.insert("gauges".into(), Json::Object(gauges));
        Json::Object(doc)
    }
}

/// Schema tag stamped into every `BENCH_*.json` document; the checker
/// rejects documents carrying any other tag.
pub const BENCH_SCHEMA: &str = "sm3-telemetry-bench-v1";

/// Build a complete `BENCH_*.json` document:
/// `{schema, bench, quick, spans, counters, gauges}`.
pub fn bench_doc(bench: &str, quick: bool, reg: &Registry) -> Json {
    let mut doc = match reg.to_json() {
        Json::Object(m) => m,
        _ => unreachable!("Registry::to_json returns an object"),
    };
    doc.insert("schema".into(), Json::String(BENCH_SCHEMA.to_string()));
    doc.insert("bench".into(), Json::String(bench.to_string()));
    doc.insert("quick".into(), Json::Bool(quick));
    Json::Object(doc)
}

fn field_u64(obj: &Json, key: &str, ctx: &str) -> Result<u64, String> {
    let n = obj
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{ctx}: missing numeric field `{key}`"))?;
    if !n.is_finite() || n < 0.0 {
        return Err(format!("{ctx}: field `{key}` = {n} is not a count"));
    }
    Ok(n as u64)
}

/// Validate a parsed `BENCH_*.json` document against the documented
/// schema (EXPERIMENTS.md §Telemetry). Returns the offending detail on
/// mismatch; CI runs this via `sm3-train bench-check`.
pub fn validate_bench_doc(doc: &Json) -> Result<(), String> {
    let obj = doc.as_object().ok_or("document is not a JSON object")?;
    match obj.get("schema").and_then(Json::as_str) {
        Some(s) if s == BENCH_SCHEMA => {}
        Some(s) => return Err(format!("unknown schema tag `{s}`")),
        None => return Err("missing string field `schema`".into()),
    }
    if obj.get("bench").and_then(Json::as_str).is_none() {
        return Err("missing string field `bench`".into());
    }
    if !matches!(obj.get("quick"), Some(Json::Bool(_))) {
        return Err("missing bool field `quick`".into());
    }
    let spans = obj
        .get("spans")
        .and_then(Json::as_object)
        .ok_or("missing object field `spans`")?;
    for (name, s) in spans {
        let ctx = format!("span `{name}`");
        let count = field_u64(s, "count", &ctx)?;
        let total = field_u64(s, "total_ns", &ctx)?;
        let min = field_u64(s, "min_ns", &ctx)?;
        let max = field_u64(s, "max_ns", &ctx)?;
        if s.get("mean_ns").and_then(Json::as_f64).is_none() {
            return Err(format!("{ctx}: missing numeric field `mean_ns`"));
        }
        if count == 0 {
            return Err(format!("{ctx}: exported with count == 0"));
        }
        if min > max || max > total {
            return Err(format!(
                "{ctx}: inconsistent stats min={min} max={max} total={total}"
            ));
        }
    }
    let counters = obj
        .get("counters")
        .and_then(Json::as_object)
        .ok_or("missing object field `counters`")?;
    for (name, v) in counters {
        if v.as_f64().filter(|n| n.is_finite() && *n >= 0.0).is_none() {
            return Err(format!("counter `{name}` is not a count"));
        }
    }
    let gauges = obj
        .get("gauges")
        .and_then(Json::as_object)
        .ok_or("missing object field `gauges`")?;
    for (name, g) in gauges {
        let ctx = format!("gauge `{name}`");
        let last = field_u64(g, "last", &ctx)?;
        let peak = field_u64(g, "peak", &ctx)?;
        if last > peak {
            return Err(format!("{ctx}: last={last} exceeds peak={peak}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_stats_track_min_mean_max_total() {
        let mut s = SpanStats::new();
        for ns in [30, 10, 20] {
            s.record(ns);
        }
        assert_eq!(s.count, 3);
        assert_eq!(s.total_ns, 60);
        assert_eq!(s.min_ns, 10);
        assert_eq!(s.max_ns, 30);
        assert_eq!(s.mean_ns(), 20.0);
    }

    #[test]
    fn merge_is_grouping_independent() {
        // Fold the same 6 samples as (a+b)+c and a+(b+c) and flat —
        // the aggregate must be identical: merge ordering across
        // worker partials cannot affect the run summary.
        let samples = [5u64, 9, 1, 7, 3, 8];
        let part = |range: std::ops::Range<usize>| {
            let mut s = SpanStats::new();
            for &ns in &samples[range] {
                s.record(ns);
            }
            s
        };
        let (a, b, c) = (part(0..2), part(2..4), part(4..6));

        let mut left = SpanStats::new();
        left.merge(&a);
        left.merge(&b);
        left.merge(&c);

        let mut ab = a;
        ab.merge(&b);
        let mut right = SpanStats::new();
        right.merge(&ab);
        right.merge(&c);

        let mut flat = SpanStats::new();
        for &ns in &samples {
            flat.record(ns);
        }
        assert_eq!(left, right);
        assert_eq!(left, flat);
    }

    #[test]
    fn gauge_keeps_high_water_mark() {
        let mut g = GaugeStats::default();
        g.set(10);
        g.set(100);
        g.set(7);
        assert_eq!(g.last, 7);
        assert_eq!(g.peak, 100);
    }

    #[test]
    fn registry_merge_matches_direct_recording() {
        let mut direct = Registry::new();
        let mut w0 = Registry::new();
        let mut w1 = Registry::new();
        for (reg, ns) in [(&mut w0, 4u64), (&mut w1, 6)] {
            reg.record_ns("opt_worker", ns);
            reg.add("items", 2);
            reg.gauge("bytes", ns * 100);
        }
        for ns in [4u64, 6] {
            direct.record_ns("opt_worker", ns);
            direct.add("items", 2);
            direct.gauge("bytes", ns * 100);
        }
        let mut merged = Registry::new();
        merged.merge(&w0);
        merged.merge(&w1);
        assert_eq!(merged, direct);
    }

    /// ISSUE 10 satellite (gauge high-water semantics): one process
    /// driving two consecutive bench sections must not leak section A's
    /// peak into section B's report. Without `reset_run` the second
    /// section's `mem/pool_bytes_peak` still reads the first section's
    /// larger high-water mark; with it, each section reports its own.
    #[test]
    fn reset_run_isolates_consecutive_bench_sections() {
        let mut reg = Registry::new();
        // section A: a large config peaks at 8 MiB
        reg.gauge("mem/pool_bytes_peak", 8 << 20);
        reg.gauge("mem/pool_bytes_peak", 1 << 20);
        reg.gauge("comm/inflight_buckets", 2);
        reg.gauge("comm/inflight_buckets", 1);
        assert_eq!(reg.gauge_stats("mem/pool_bytes_peak").unwrap().peak,
                   8 << 20);
        // the leak this guards against: section B (small config) still
        // reports section A's peak
        reg.gauge("mem/pool_bytes_peak", 2 << 20);
        assert_eq!(reg.gauge_stats("mem/pool_bytes_peak").unwrap().peak,
                   8 << 20, "without reset_run the peak leaks");
        // re-arm between sections: B's peak describes B alone
        reg.reset_run();
        assert_eq!(reg.gauge_stats("comm/inflight_buckets").unwrap().peak,
                   1, "re-armed at the live level");
        reg.gauge("mem/pool_bytes_peak", 3 << 20);
        let g = reg.gauge_stats("mem/pool_bytes_peak").unwrap();
        assert_eq!((g.last, g.peak), (3 << 20, 3 << 20));
        // spans/counters keep accumulating across sections
        reg.record_ns("opt_step", 10);
        reg.add("comm/exchanges", 1);
        reg.reset_run();
        assert_eq!(reg.span("opt_step").unwrap().count, 1);
        assert_eq!(reg.counter("comm/exchanges"), Some(1));
    }

    #[test]
    fn bench_doc_passes_own_validator() {
        let mut reg = Registry::new();
        reg.record_ns("comm/hop_reduce", 1_500);
        reg.record_ns("comm/hop_reduce", 2_500);
        reg.add("comm/wire_bytes", 4096);
        reg.gauge("mem/comm_buffer_bytes", 1 << 20);
        let doc = bench_doc("bench_collectives", true, &reg);
        validate_bench_doc(&doc).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        // wrong schema tag
        let mut reg = Registry::new();
        reg.record_ns("x", 1);
        let doc = bench_doc("b", false, &reg);
        let mut bad = doc.as_object().unwrap().clone();
        bad.insert("schema".into(), Json::String("v0".into()));
        assert!(validate_bench_doc(&Json::Object(bad.clone())).is_err());
        // missing spans section
        let mut no_spans = doc.as_object().unwrap().clone();
        no_spans.remove("spans");
        assert!(validate_bench_doc(&Json::Object(no_spans)).is_err());
        // span with inconsistent stats
        let text = r#"{"schema":"sm3-telemetry-bench-v1","bench":"b",
            "quick":true,"counters":{},"gauges":{},
            "spans":{"p":{"count":1,"total_ns":5,"min_ns":9,
                          "max_ns":9,"mean_ns":5.0}}}"#;
        let parsed = Json::parse(text).unwrap();
        assert!(validate_bench_doc(&parsed).is_err());
    }
}
