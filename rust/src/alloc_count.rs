//! Thread-local allocation counting for the steady-state
//! allocation-free tests (ISSUE 3 satellite).
//!
//! Compiled into the lib's own test harness only (`#[cfg(test)]` at the
//! `lib.rs` module declaration): release builds and integration tests
//! use the plain system allocator. The counter is per-thread, so
//! concurrently running unit tests on other harness threads cannot
//! perturb a measurement — a test reads [`thread_allocs`] before and
//! after the code under test on its own thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-initialized: no lazy init and no Drop, so touching it from
    // inside the allocator can itself never allocate
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap acquisitions (alloc / alloc_zeroed / realloc) observed on the
/// calling thread since it started.
pub fn thread_allocs() -> u64 {
    ALLOCS.with(Cell::get)
}

pub struct CountingAlloc;

#[inline]
fn bump() {
    // try_with: TLS may be unavailable during thread teardown — skip
    // counting there rather than aborting inside the allocator
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_allocations_on_this_thread() {
        let before = thread_allocs();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocs();
        assert!(after > before, "allocation not counted");
        drop(v);
        // pure arithmetic does not bump the counter
        let b2 = thread_allocs();
        let x = std::hint::black_box(3u64) * 7;
        assert_eq!(thread_allocs(), b2);
        assert_eq!(x, 21);
    }
}
