//! Thread-local allocation counting for the steady-state
//! allocation-free tests (ISSUE 3 satellite) and live/peak heap-byte
//! tracking for the telemetry memory-gauge cross-checks (ISSUE 7).
//!
//! Compiled into the lib's own test harness only (`#[cfg(test)]` at the
//! `lib.rs` module declaration): release builds and integration tests
//! use the plain system allocator. All counters are per-thread, so
//! concurrently running unit tests on other harness threads cannot
//! perturb a measurement — a test reads [`thread_allocs`] /
//! [`thread_live_bytes`] before and after the code under test on its
//! own thread.
//!
//! Byte accounting is a lower-bound bracket, not an exact mirror:
//! `dealloc` of memory allocated before counting started (or handed
//! across threads) saturates at zero rather than underflowing, and the
//! peak resets only via [`reset_thread_peak_bytes`].

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-initialized: no lazy init and no Drop, so touching these
    // from inside the allocator can itself never allocate
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static LIVE_BYTES: Cell<u64> = const { Cell::new(0) };
    static PEAK_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Heap acquisitions (alloc / alloc_zeroed / realloc) observed on the
/// calling thread since it started.
pub fn thread_allocs() -> u64 {
    ALLOCS.with(Cell::get)
}

/// Bytes currently held by allocations made (and not yet freed) on the
/// calling thread.
pub fn thread_live_bytes() -> u64 {
    LIVE_BYTES.with(Cell::get)
}

/// High-water mark of [`thread_live_bytes`] since thread start or the
/// last [`reset_thread_peak_bytes`].
pub fn thread_peak_bytes() -> u64 {
    PEAK_BYTES.with(Cell::get)
}

/// Re-arm the peak tracker at the current live level so a test can
/// measure the high-water mark of just the code under test.
pub fn reset_thread_peak_bytes() {
    let live = LIVE_BYTES.with(Cell::get);
    PEAK_BYTES.with(|c| c.set(live));
}

pub struct CountingAlloc;

#[inline]
fn bump(bytes: u64) {
    // try_with: TLS may be unavailable during thread teardown — skip
    // counting there rather than aborting inside the allocator
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = LIVE_BYTES.try_with(|c| {
        let live = c.get() + bytes;
        c.set(live);
        let _ = PEAK_BYTES.try_with(|p| p.set(p.get().max(live)));
    });
}

#[inline]
fn shrink(bytes: u64) {
    let _ = LIVE_BYTES.try_with(|c| c.set(c.get().saturating_sub(bytes)));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(layout.size() as u64);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(layout.size() as u64);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        // count as one acquisition; live bytes move by the size delta
        if new_size >= layout.size() {
            bump((new_size - layout.size()) as u64);
        } else {
            let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            shrink((layout.size() - new_size) as u64);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        shrink(layout.size() as u64);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_allocations_on_this_thread() {
        let before = thread_allocs();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocs();
        assert!(after > before, "allocation not counted");
        drop(v);
        // pure arithmetic does not bump the counter
        let b2 = thread_allocs();
        let x = std::hint::black_box(3u64) * 7;
        assert_eq!(thread_allocs(), b2);
        assert_eq!(x, 21);
    }

    #[test]
    fn tracks_live_and_peak_bytes() {
        reset_thread_peak_bytes();
        let live0 = thread_live_bytes();
        let v: Vec<u8> = Vec::with_capacity(1 << 16);
        assert!(thread_live_bytes() >= live0 + (1 << 16),
                "64 KiB allocation must show up in live bytes");
        assert!(thread_peak_bytes() >= thread_live_bytes());
        drop(v);
        assert!(thread_live_bytes() < live0 + (1 << 16),
                "freeing must shrink live bytes");
        // the peak keeps the high-water mark after the free
        assert!(thread_peak_bytes() >= live0 + (1 << 16));
        // re-arming brings the peak back down to the live level
        reset_thread_peak_bytes();
        assert_eq!(thread_peak_bytes(), thread_live_bytes());
    }
}
