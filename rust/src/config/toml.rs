//! TOML-subset parser (serde/toml crates unavailable offline).
//!
//! Supports the subset the config system uses: `[table]` and
//! `[nested.table]` headers, `[[array.of.tables]]` headers (each opens a
//! fresh table appended to the array — the `[[optim.group]]` param-group
//! syntax), `key = value` pairs with string / integer / float / boolean /
//! array values, comments, and blank lines. Path components that name an
//! array of tables resolve to its *last* element, per the TOML spec.
//! Unsupported TOML (multi-line strings, dotted keys, inline tables,
//! dates) is rejected with a line-numbered error rather than mis-parsed.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    pub fn empty_table() -> Self {
        TomlValue::Table(BTreeMap::new())
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric access accepting both int and float literals.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError { line, msg: msg.into() }
}

/// Parse TOML text into a root table.
pub fn parse(text: &str) -> Result<TomlValue, TomlError> {
    let mut root = BTreeMap::new();
    // path of the currently-open table
    let mut current: Vec<String> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix("[[") {
            // array-of-tables header: append a fresh table to the array
            // at `path`; subsequent keys land in that element
            let header = header
                .strip_suffix("]]")
                .ok_or_else(|| err(lineno, "unclosed array-of-tables \
                                            header"))?;
            current = header.split('.').map(|p| p.trim().to_string()).collect();
            if current.iter().any(String::is_empty) {
                return Err(err(lineno, "empty table-name component"));
            }
            let (parent, last) = current.split_at(current.len() - 1);
            let tbl = table_at(&mut root, parent, lineno)?;
            let entry = tbl
                .entry(last[0].clone())
                .or_insert_with(|| TomlValue::Array(Vec::new()));
            match entry {
                TomlValue::Array(items) => {
                    // appending to a statically-defined array of scalars
                    // (`xs = [1]` then `[[xs]]`) is a TOML error — reject
                    // instead of building a heterogeneous array
                    if items.iter()
                        .any(|it| !matches!(it, TomlValue::Table(_)))
                    {
                        return Err(err(lineno, format!(
                            "{:?} is an array of values, not of tables",
                            last[0])));
                    }
                    items.push(TomlValue::empty_table());
                }
                _ => {
                    return Err(err(lineno, format!(
                        "{:?} is not an array of tables", last[0])));
                }
            }
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unclosed table header"))?;
            current = header.split('.').map(|p| p.trim().to_string()).collect();
            if current.iter().any(String::is_empty) {
                return Err(err(lineno, "empty table-name component"));
            }
            // a single-bracket header must not name an existing array of
            // tables — TOML rejects `[x]` after `[[x]]`, and silently
            // resolving to the last element would merge the keys into
            // the previous array entry (parent components may still
            // traverse arrays: `[job.opts]` after `[[job]]` is fine)
            let (parent, last) = current.split_at(current.len() - 1);
            let tbl = table_at(&mut root, parent, lineno)?;
            if matches!(tbl.get(&last[0]), Some(TomlValue::Array(_))) {
                return Err(err(lineno, format!(
                    "{:?} is an array of tables — append entries with \
                     [[{header}]]", last[0])));
            }
            // ensure the table exists
            table_at(&mut root, &current, lineno)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        if key.contains('.') {
            return Err(err(lineno, "dotted keys not supported"));
        }
        let key = key.trim_matches('"').to_string();
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let tbl = table_at(&mut root, &current, lineno)?;
        if tbl.insert(key.clone(), value).is_some() {
            return Err(err(lineno, format!("duplicate key {key:?}")));
        }
    }
    Ok(TomlValue::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // a `#` outside of a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn table_at<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, TomlValue>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(TomlValue::empty_table);
        match entry {
            TomlValue::Table(m) => cur = m,
            // a path component naming an array of tables resolves to
            // its most recent element (TOML semantics)
            TomlValue::Array(items) => match items.last_mut() {
                Some(TomlValue::Table(m)) => cur = m,
                _ => {
                    return Err(err(lineno, format!(
                        "{part:?} is not an array of tables")));
                }
            },
            _ => return Err(err(lineno, format!("{part:?} is not a table"))),
        }
    }
    Ok(cur)
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quote not supported"));
        }
        return Ok(TomlValue::String(unescape(inner)));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), lineno)?);
        }
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Integer(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, format!("cannot parse value {s:?}")))
}

fn unescape(s: &str) -> String {
    s.replace("\\n", "\n").replace("\\t", "\t").replace("\\\\", "\\")
}

/// Split an array body on commas not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let t = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(t.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(t.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_tables_and_nesting() {
        let t = parse("[a]\nx = 1\n[a.b]\ny = 2\n[c]\nz = 3\n").unwrap();
        assert_eq!(t.get("a").unwrap().get("x").unwrap().as_i64(), Some(1));
        assert_eq!(t.get("a").unwrap().get("b").unwrap()
                       .get("y").unwrap().as_i64(), Some(2));
        assert_eq!(t.get("c").unwrap().get("z").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn parses_arrays() {
        let t = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nzs = []\n").unwrap();
        assert_eq!(t.get("xs").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(t.get("ys").unwrap().as_array().unwrap()[1].as_str(),
                   Some("b"));
        assert!(t.get("zs").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let t = parse("# header\n\na = 1  # trailing\nb = \"#not a comment\"\n")
            .unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get("b").unwrap().as_str(), Some("#not a comment"));
    }

    #[test]
    fn scientific_notation_floats() {
        let t = parse("lr = 4.5e-4\nneg = -1e3\n").unwrap();
        assert!((t.get("lr").unwrap().as_f64().unwrap() - 4.5e-4).abs() < 1e-12);
        assert_eq!(t.get("neg").unwrap().as_f64(), Some(-1000.0));
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn unsupported_syntax_rejected_not_misparsed() {
        assert!(parse("a.b = 1\n").is_err());
        assert!(parse("[[unclosed.array\n").is_err());
        // a scalar key cannot become an array of tables
        assert!(parse("x = 1\n[[x]]\n").is_err());
        // nor can [[x]] append to a statically-defined scalar array
        assert!(parse("xs = [1, 2]\n[[xs]]\na = 1\n").is_err());
        // ...and a plain [x] header must not open (and merge into) the
        // last element of an existing [[x]] array
        assert!(parse("[[x]]\na = 1\n[x]\nb = 2\n").is_err());
        // nor can keys land under an array of scalars
        assert!(parse("xs = [1, 2]\n[xs.y]\nz = 1\n").is_err());
    }

    /// `[[optim.group]]` — each header opens a fresh table appended to
    /// the array; keys after it land in that element.
    #[test]
    fn array_of_tables_parses() {
        let t = parse(
            "[optim]\nname = \"adam\"\n\n[[optim.group]]\n\
             pattern = \"*bias*\"\nweight_decay = 0.0\n\n\
             [[optim.group]]\npattern = \"embed\"\nlr_scale = 0.5\n")
            .unwrap();
        let groups = t.get("optim").unwrap().get("group").unwrap()
            .as_array().unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].get("pattern").unwrap().as_str(),
                   Some("*bias*"));
        assert_eq!(groups[0].get("weight_decay").unwrap().as_f64(),
                   Some(0.0));
        assert_eq!(groups[1].get("pattern").unwrap().as_str(),
                   Some("embed"));
        assert_eq!(groups[1].get("lr_scale").unwrap().as_f64(), Some(0.5));
        // the sibling scalar key is untouched
        assert_eq!(t.get("optim").unwrap().get("name").unwrap().as_str(),
                   Some("adam"));
    }

    /// Top-level arrays of tables and nested tables under the last
    /// array element both resolve per the TOML spec.
    #[test]
    fn array_of_tables_nesting() {
        let t = parse("[[job]]\nid = 1\n[job.opts]\nfast = true\n\
                       [[job]]\nid = 2\n").unwrap();
        let jobs = t.get("job").unwrap().as_array().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("id").unwrap().as_i64(), Some(1));
        assert_eq!(jobs[0].get("opts").unwrap().get("fast").unwrap()
                       .as_bool(), Some(true));
        assert_eq!(jobs[1].get("id").unwrap().as_i64(), Some(2));
        assert!(jobs[1].get("opts").is_none());
    }
}
