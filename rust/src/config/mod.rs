//! Typed experiment configuration, loaded from TOML files in `configs/`.
//!
//! A config fully determines a training run: which model artifacts to use,
//! optimizer + hyperparameters (paper Table 3), LR schedule (Table 4),
//! data-generation seed, worker topology. `TrainConfig::load` parses the
//! TOML (via the in-repo [`toml`] parser — serde is unavailable offline),
//! applies defaults, and validates.

pub mod toml;

use self::toml::TomlValue;
use crate::optim::StateDtype;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Optimizer selection + hyperparameters (paper Table 3).
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// "sm3" | "sm3i" | "adagrad" | "adam" | "adafactor" | "sgdm"
    pub name: String,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    /// "constant" | "rsqrt" | "linear" | "staircase" | "paper" (Table 4)
    pub schedule: String,
    pub warmup_steps: u64,
    /// staircase floor η₀ (staircase schedule / sgdm "paper" default);
    /// `None` derives `lr · 0.01` — the historically hard-coded value
    pub lr_eta0: Option<f64>,
    /// staircase per-stair decay α, must be in (0, 1)
    pub lr_alpha: f64,
    /// staircase stair width τ in steps; `None` derives `max(steps/10, 1)`
    pub lr_tau: Option<u64>,
}

impl Default for OptimConfig {
    fn default() -> Self {
        Self {
            name: "sm3".into(),
            lr: 0.1,
            beta1: 0.9,
            beta2: 0.98,
            schedule: "constant".into(),
            warmup_steps: 100,
            lr_eta0: None,
            lr_alpha: 0.88,
            lr_tau: None,
        }
    }
}

impl OptimConfig {
    /// The staircase parameter bundle the schedule resolver consumes.
    pub fn staircase_params(&self) -> crate::optim::schedule::StaircaseParams {
        crate::optim::schedule::StaircaseParams {
            eta0: self.lr_eta0,
            alpha: self.lr_alpha,
            tau: self.lr_tau,
        }
    }
}

/// Execution path through the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// fused HLO artifact: fwd+bwd+optimizer inside XLA (fast path)
    Fused,
    /// grad artifact + Rust optimizer bank (flexible path)
    Split,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fused" => ExecMode::Fused,
            "split" => ExecMode::Split,
            other => bail!("unknown exec mode {other:?} (fused|split)"),
        })
    }
}

/// A complete training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// model key in the artifact manifest ("lm_small", "mt_small", ...)
    pub model: String,
    pub optim: OptimConfig,
    pub exec: ExecMode,
    /// total optimizer steps
    pub steps: u64,
    /// evaluate every N steps
    pub eval_every: u64,
    /// microbatches accumulated per optimizer step (simulated large batch)
    pub grad_accum: u64,
    /// data-parallel worker count (simulated cores)
    pub workers: usize,
    /// host threads sharding the optimizer update (split path); 1 = serial.
    /// Training results (parameter values) are bitwise identical at any
    /// value; optimizer-state *checkpoint layout* differs from serial for
    /// optimizers with global slots (Adam's `t`) — see `optim::parallel`.
    pub step_threads: usize,
    /// storage precision for optimizer-state slots (split path):
    /// "f32" | "bf16" | "q8" — see `optim::qstate` / DESIGN.md §10.
    /// Composes with `step_threads` (bitwise-identical at any count).
    pub state_dtype: StateDtype,
    /// streaming tile for the chunked step kernels, in elements (split
    /// path; must be a positive multiple of 64 — the q8 block). Affects
    /// traversal granularity only: results are bitwise identical at any
    /// value. See `optim::kernel` / DESIGN.md §10.
    pub step_chunk: usize,
    /// RNG seed for data + init
    pub seed: u64,
    /// artifact directory
    pub artifacts_dir: String,
    /// output directory for metric CSVs
    pub out_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "lm_small".into(),
            optim: OptimConfig::default(),
            exec: ExecMode::Split,
            steps: 200,
            eval_every: 20,
            grad_accum: 1,
            workers: 1,
            step_threads: 1,
            state_dtype: StateDtype::F32,
            step_chunk: crate::optim::kernel::DEFAULT_CHUNK,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
        }
    }
}

fn get_str(t: &TomlValue, key: &str, default: &str) -> String {
    t.get(key).and_then(TomlValue::as_str).map(String::from)
        .unwrap_or_else(|| default.to_string())
}

fn get_f64(t: &TomlValue, key: &str, default: f64) -> f64 {
    t.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
}

fn get_u64(t: &TomlValue, key: &str, default: u64) -> u64 {
    t.get(key).and_then(TomlValue::as_i64).map(|v| v as u64)
        .unwrap_or(default)
}

impl TrainConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let root = toml::parse(text).context("parsing config TOML")?;
        let d = TrainConfig::default();
        let od = OptimConfig::default();

        let optim_tbl = root.get("optim").cloned()
            .unwrap_or(TomlValue::empty_table());
        let optim = OptimConfig {
            name: get_str(&optim_tbl, "name", &od.name),
            lr: get_f64(&optim_tbl, "lr", od.lr),
            beta1: get_f64(&optim_tbl, "beta1", od.beta1),
            beta2: get_f64(&optim_tbl, "beta2", od.beta2),
            schedule: get_str(&optim_tbl, "schedule", &od.schedule),
            warmup_steps: get_u64(&optim_tbl, "warmup_steps", od.warmup_steps),
            lr_eta0: optim_tbl.get("lr_eta0").and_then(TomlValue::as_f64),
            lr_alpha: get_f64(&optim_tbl, "lr_alpha", od.lr_alpha),
            lr_tau: match optim_tbl.get("lr_tau").and_then(TomlValue::as_i64) {
                // reject instead of casting: -1 as u64 would wrap to a
                // huge stair width and "pass" the tau >= 1 check
                Some(v) if v < 1 => bail!("[optim] lr_tau must be >= 1, \
                                           got {v}"),
                Some(v) => Some(v as u64),
                None => None,
            },
        };

        let train_tbl = root.get("train").cloned()
            .unwrap_or(TomlValue::empty_table());
        let cfg = Self {
            model: get_str(&train_tbl, "model", &d.model),
            exec: ExecMode::parse(&get_str(&train_tbl, "exec", "split"))?,
            steps: get_u64(&train_tbl, "steps", d.steps),
            eval_every: get_u64(&train_tbl, "eval_every", d.eval_every),
            grad_accum: get_u64(&train_tbl, "grad_accum", d.grad_accum),
            workers: get_u64(&train_tbl, "workers", d.workers as u64) as usize,
            step_threads: get_u64(&train_tbl, "step_threads",
                                  d.step_threads as u64) as usize,
            state_dtype: StateDtype::parse(&get_str(
                &train_tbl, "state_dtype", d.state_dtype.name()))
                .context("[train] state_dtype")?,
            step_chunk: match train_tbl.get("step_chunk")
                .and_then(TomlValue::as_i64)
            {
                // reject instead of casting: -64 as u64 would wrap to a
                // positive multiple of 64 and sail through check_chunk
                Some(v) if v < 1 => bail!("[train] step_chunk must be \
                                           >= 1, got {v}"),
                Some(v) => v as usize,
                None => d.step_chunk,
            },
            seed: get_u64(&train_tbl, "seed", d.seed),
            artifacts_dir: get_str(&train_tbl, "artifacts_dir",
                                   &d.artifacts_dir),
            out_dir: get_str(&train_tbl, "out_dir", &d.out_dir),
            optim,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn validate(&self) -> Result<()> {
        if !crate::optim::ALL.contains(&self.optim.name.as_str()) {
            bail!("unknown optimizer {:?}", self.optim.name);
        }
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.grad_accum == 0 || self.workers == 0 {
            bail!("grad_accum and workers must be > 0");
        }
        if self.step_threads == 0 {
            bail!("step_threads must be > 0 (1 = serial)");
        }
        if self.step_threads > 1 && self.exec == ExecMode::Fused {
            bail!("step_threads applies to the split path only (the fused \
                   artifact already contains the optimizer)");
        }
        if self.state_dtype != StateDtype::F32 && self.exec == ExecMode::Fused {
            bail!("state_dtype = {:?} applies to the split path only (the \
                   fused artifact keeps its optimizer state in f32 device \
                   buffers)", self.state_dtype.name());
        }
        crate::optim::kernel::check_chunk(self.step_chunk)
            .context("[train] step_chunk")?;
        if self.step_chunk != crate::optim::kernel::DEFAULT_CHUNK
            && self.exec == ExecMode::Fused
        {
            bail!("step_chunk applies to the split path only (the fused \
                   artifact already contains the optimizer)");
        }
        if !(0.0..1.0).contains(&self.optim.beta1) {
            bail!("beta1 out of range");
        }
        if self.optim.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if !matches!(self.optim.schedule.as_str(),
                     "paper" | "constant" | "rsqrt" | "linear" | "staircase")
        {
            bail!("unknown schedule {:?} (paper|constant|rsqrt|linear|\
                   staircase)", self.optim.schedule);
        }
        // staircase parameters: validated here so a bad config fails at
        // parse time, not mid-run (resolve re-checks at schedule build)
        self.optim.staircase_params()
            .resolve(self.optim.lr, self.steps)
            .context("[optim] lr_eta0 / lr_alpha / lr_tau")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.model, "lm_small");
        assert_eq!(cfg.optim.name, "sm3");
        assert_eq!(cfg.exec, ExecMode::Split);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
[train]
model = "mt_small"
exec = "fused"
steps = 500
eval_every = 50
grad_accum = 2
workers = 4
seed = 7

[optim]
name = "adafactor"
lr = 0.00045
beta1 = 0.9
beta2 = 0.98
schedule = "rsqrt"
warmup_steps = 40
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, "mt_small");
        assert_eq!(cfg.exec, ExecMode::Fused);
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.optim.name, "adafactor");
        assert!((cfg.optim.lr - 0.00045).abs() < 1e-12);
        assert_eq!(cfg.optim.schedule, "rsqrt");
    }

    #[test]
    fn step_threads_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.step_threads, 1);
        let cfg =
            TrainConfig::from_toml("[train]\nstep_threads = 4\n").unwrap();
        assert_eq!(cfg.step_threads, 4);
        assert!(TrainConfig::from_toml("[train]\nstep_threads = 0\n").is_err());
        // sharded stepping is a split-path feature; fused must reject it
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstep_threads = 4\n").is_err());
    }

    #[test]
    fn state_dtype_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::F32);
        let cfg =
            TrainConfig::from_toml("[train]\nstate_dtype = \"q8\"\n").unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::Q8);
        let cfg =
            TrainConfig::from_toml("[train]\nstate_dtype = \"bf16\"\n")
                .unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::Bf16);
        // unknown dtype names must fail with a message, not default
        assert!(TrainConfig::from_toml(
            "[train]\nstate_dtype = \"fp8\"\n").is_err());
        // quantized state is a split-path feature; fused must reject it
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstate_dtype = \"q8\"\n").is_err());
        // fused + explicit f32 is fine (it is the fused behavior anyway)
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstate_dtype = \"f32\"\n").is_ok());
        // quantized state composes with sharded stepping
        let cfg = TrainConfig::from_toml(
            "[train]\nstep_threads = 4\nstate_dtype = \"q8\"\n").unwrap();
        assert_eq!((cfg.step_threads, cfg.state_dtype),
                   (4, StateDtype::Q8));
    }

    #[test]
    fn step_chunk_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.step_chunk, crate::optim::kernel::DEFAULT_CHUNK);
        let cfg =
            TrainConfig::from_toml("[train]\nstep_chunk = 128\n").unwrap();
        assert_eq!(cfg.step_chunk, 128);
        // must be a positive multiple of the q8 block; negatives must
        // error rather than wrap through `as u64` (−64 would wrap to a
        // huge multiple of 64)
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = 0\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = 100\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = -64\n").is_err());
        // split-path knob: fused rejects a non-default tile
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstep_chunk = 128\n").is_err());
        // composes with sharding and quantized state
        let cfg = TrainConfig::from_toml(
            "[train]\nstep_threads = 4\nstate_dtype = \"q8\"\n\
             step_chunk = 256\n").unwrap();
        assert_eq!((cfg.step_threads, cfg.state_dtype, cfg.step_chunk),
                   (4, StateDtype::Q8, 256));
    }

    /// ISSUE 3 satellite: the staircase schedule's η₀/α/τ come from the
    /// config (defaults preserved), and α is range-checked at parse time.
    #[test]
    fn staircase_lr_params_parse_and_validate() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.optim.lr_eta0, None);
        assert_eq!(cfg.optim.lr_alpha, 0.88);
        assert_eq!(cfg.optim.lr_tau, None);
        let cfg = TrainConfig::from_toml(
            "[optim]\nschedule = \"staircase\"\nlr_eta0 = 0.003\n\
             lr_alpha = 0.5\nlr_tau = 400\n").unwrap();
        assert_eq!(cfg.optim.lr_eta0, Some(0.003));
        assert_eq!(cfg.optim.lr_alpha, 0.5);
        assert_eq!(cfg.optim.lr_tau, Some(400));
        let p = cfg.optim.staircase_params();
        assert_eq!(p.resolve(cfg.optim.lr, cfg.steps).unwrap(),
                   (0.003, 0.5, 400));
        // 0 < alpha < 1 enforced at config parse, any schedule
        assert!(TrainConfig::from_toml("[optim]\nlr_alpha = 1.0\n").is_err());
        assert!(TrainConfig::from_toml("[optim]\nlr_alpha = 0.0\n").is_err());
        assert!(TrainConfig::from_toml(
            "[optim]\nschedule = \"staircase\"\nlr_alpha = 2.0\n").is_err());
        // unknown schedule names now fail instead of silently falling
        // back to constant
        assert!(TrainConfig::from_toml(
            "[optim]\nschedule = \"cosine\"\n").is_err());
        // negative lr_tau must error, not wrap through `as u64`
        assert!(TrainConfig::from_toml("[optim]\nlr_tau = -1\n").is_err());
        assert!(TrainConfig::from_toml("[optim]\nlr_tau = 0\n").is_err());
    }

    #[test]
    fn rejects_bad_optimizer() {
        assert!(TrainConfig::from_toml("[optim]\nname = \"zzz\"\n").is_err());
    }

    #[test]
    fn rejects_zero_steps() {
        assert!(TrainConfig::from_toml("[train]\nsteps = 0\n").is_err());
    }

    #[test]
    fn rejects_bad_exec_mode() {
        assert!(TrainConfig::from_toml("[train]\nexec = \"warp\"\n").is_err());
    }
}
