//! Typed experiment configuration, loaded from TOML files in `configs/`.
//!
//! A config fully determines a training run: which model artifacts to use,
//! optimizer + hyperparameters (paper Table 3), LR schedule (Table 4),
//! data-generation seed, worker topology. `TrainConfig::load` parses the
//! TOML (via the in-repo [`toml`] parser — serde is unavailable offline),
//! applies defaults, and validates.

pub mod toml;

use self::toml::TomlValue;
use crate::comms::TransportKind;
use crate::health::HealthAction;
use crate::optim::{Backend, GroupSpec, OptimSpec, SplitPolicy, StateDtype};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Adam's historic denominator stabilizer — the value that was pinned
/// inside the constructors before `[optim] eps` existed.
pub const DEFAULT_EPS: f64 = 1e-8;

/// One `[[optim.group]]` entry: per-parameter-group overrides resolved
/// against leaf names at build time (see `optim::GroupSpec` for the
/// pattern grammar and the most-specific-wins rule).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupConfig {
    /// Name-prefix or `*`-glob over parameter names (required).
    pub pattern: String,
    /// LR multiplier for matched leaves (default 1.0).
    pub lr_scale: f64,
    /// Weight-decay override for matched leaves (e.g. 0.0 on biases).
    pub weight_decay: Option<f64>,
}

/// Optimizer selection + hyperparameters (paper Table 3).
#[derive(Clone, Debug)]
pub struct OptimConfig {
    /// "sm3" | "sm3i" | "adagrad" | "adam" | "adafactor" | "sgdm"
    pub name: String,
    /// Base learning rate (pre-schedule).
    pub lr: f64,
    /// Momentum / first-moment decay β₁ (every method).
    pub beta1: f64,
    /// Second-moment decay β₂ (Adam, Adafactor).
    pub beta2: f64,
    /// Adam's denominator stabilizer ε (split path; default 1e-8 — the
    /// historically hard-coded value). Ignored by the other methods.
    pub eps: f64,
    /// "constant" | "rsqrt" | "linear" | "staircase" | "paper" (Table 4)
    pub schedule: String,
    /// Linear LR warmup steps.
    pub warmup_steps: u64,
    /// staircase floor η₀ (staircase schedule / sgdm "paper" default);
    /// `None` derives `lr · 0.01` — the historically hard-coded value
    pub lr_eta0: Option<f64>,
    /// staircase per-stair decay α, must be in (0, 1)
    pub lr_alpha: f64,
    /// staircase stair width τ in steps; `None` derives `max(steps/10, 1)`
    pub lr_tau: Option<u64>,
    /// `clip_by_global_norm` threshold (split path; None = no clipping).
    pub clip_norm: Option<f64>,
    /// `clip_by_value` threshold, applied before the norm clip (split
    /// path; None = no clamping).
    pub clip_value: Option<f64>,
    /// Decoupled (AdamW-style) weight-decay base rate (split path;
    /// 0 = off).
    pub weight_decay: f64,
    /// `[[optim.group]]` per-parameter-group overrides (split path).
    pub groups: Vec<GroupConfig>,
}

impl Default for OptimConfig {
    fn default() -> Self {
        Self {
            name: "sm3".into(),
            lr: 0.1,
            beta1: 0.9,
            beta2: 0.98,
            eps: DEFAULT_EPS,
            schedule: "constant".into(),
            warmup_steps: 100,
            lr_eta0: None,
            lr_alpha: 0.88,
            lr_tau: None,
            clip_norm: None,
            clip_value: None,
            weight_decay: 0.0,
            groups: Vec::new(),
        }
    }
}

impl OptimConfig {
    /// The staircase parameter bundle the schedule resolver consumes.
    pub fn staircase_params(&self) -> crate::optim::schedule::StaircaseParams {
        crate::optim::schedule::StaircaseParams {
            eta0: self.lr_eta0,
            alpha: self.lr_alpha,
            tau: self.lr_tau,
        }
    }

    /// Does this config ask for any update transform or group override
    /// (the split-path-only pipeline features)?
    pub fn has_transforms(&self) -> bool {
        self.clip_norm.is_some() || self.clip_value.is_some()
            || self.weight_decay != 0.0 || !self.groups.is_empty()
    }
}

/// Execution path through the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// fused HLO artifact: fwd+bwd+optimizer inside XLA (fast path)
    Fused,
    /// grad artifact + Rust optimizer bank (flexible path)
    Split,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fused" => ExecMode::Fused,
            "split" => ExecMode::Split,
            other => bail!("unknown exec mode {other:?} (fused|split)"),
        })
    }
}

/// A complete training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// model key in the artifact manifest ("lm_small", "mt_small", ...)
    pub model: String,
    pub optim: OptimConfig,
    pub exec: ExecMode,
    /// total optimizer steps
    pub steps: u64,
    /// evaluate every N steps
    pub eval_every: u64,
    /// microbatches accumulated per optimizer step (simulated large batch)
    pub grad_accum: u64,
    /// data-parallel worker count (simulated cores)
    pub workers: usize,
    /// host threads sharding the optimizer update (split path); 1 = serial.
    /// Training results (parameter values) are bitwise identical at any
    /// value; optimizer-state *checkpoint layout* differs from serial for
    /// optimizers with global slots (Adam's `t`) — see `optim::parallel`.
    pub step_threads: usize,
    /// storage precision for optimizer-state slots (split path):
    /// "f32" | "bf16" | "q8" — see `optim::qstate` / DESIGN.md §10.
    /// Composes with `step_threads` (bitwise-identical at any count).
    pub state_dtype: StateDtype,
    /// streaming tile for the chunked step kernels, in elements (split
    /// path; must be a positive multiple of 64 — the q8 block). Affects
    /// traversal granularity only: results are bitwise identical at any
    /// value. See `optim::kernel` / DESIGN.md §10.
    pub step_chunk: usize,
    /// wire precision of the data-parallel gradient exchange (split
    /// path, `workers > 1`): "f32" | "bf16" | "q8". Compressed dtypes
    /// add per-rank error-feedback residual state so training stays
    /// convergent. See `comms` / DESIGN.md §12.
    pub comm_dtype: StateDtype,
    /// wire tile for the ring collectives, in elements (split path;
    /// must be a positive multiple of 64 — the q8 wire block). Affects
    /// message tiling only: results are bitwise identical at any value.
    pub comm_chunk: usize,
    /// host threads executing the ring collectives (split path); 1 =
    /// serial. Results are bitwise identical at any value and any
    /// `comm_dtype` — the ring schedule fixes the reduction order.
    pub comm_threads: usize,
    /// 64-aligned flat gradient buckets the exchange pipelines over
    /// (split path; >= 1, 1 = the monolithic exchange). Pure scheduling:
    /// results are bitwise identical at any tiling bucket count — see
    /// `comms::bucket` / DESIGN.md §15.
    pub comm_buckets: usize,
    /// stage/quantize bucket k+1 while bucket k's ring hops are in
    /// flight on a dedicated hop-worker thread (split path). Bitwise
    /// identical on or off; `comm_buckets >= 2` is what buys actual
    /// overlap. See DESIGN.md §15.
    pub comm_overlap: bool,
    /// hop-edge payload path: "direct" (in-memory regions) | "inproc"
    /// (serialized messages through per-edge channel slots). Bitwise
    /// identical either way; defaults to the ambient
    /// `SM3_COMM_TRANSPORT`, direct when unset.
    pub comm_transport: TransportKind,
    /// kernel backend for the split-path hot loops (step kernels, state
    /// codecs, global-norm partials, comm wire lanes): "scalar" |
    /// "simd". A pure performance knob — every backend is bitwise
    /// identical (DESIGN.md §13). The default tracks the `simd` cargo
    /// feature.
    pub kernel_backend: Backend,
    /// route every steady-state buffer (optimizer slots/scratch, comm
    /// staging, wire slabs, transport edges, checkpoint stitches)
    /// through the size-classed memory pool (split path; DESIGN.md
    /// §16). `false` keeps the same lease API and occupancy ledger but
    /// skips free-list recycling. Bitwise identical on or off.
    pub pool: bool,
    /// enable the telemetry subsystem (split path): per-phase span
    /// timings widen the step CSV (grad/opt/comm pack/hop/unpack/ckpt
    /// ms columns) and live memory gauges are sampled at step
    /// boundaries. Determinism-neutral — trajectories are bitwise
    /// identical on or off (DESIGN.md §14).
    pub telemetry: bool,
    /// optional JSONL event-stream path (one `step` event per training
    /// step plus a final `summary` event). Requires `telemetry = true`.
    pub telemetry_jsonl: Option<String>,
    /// optional Chrome-trace output path: record every telemetry span
    /// and counter/gauge update into per-thread trace rings and write
    /// the drained timeline as Chrome-trace/Perfetto JSON at run end
    /// (DESIGN.md §17). Requires `telemetry = true`.
    pub trace_out: Option<String>,
    /// what an abort-class health verdict does: `"warn"` logs and
    /// continues (default), `"abort"` halts the run naming the tripped
    /// rule. The watchdogs themselves run whenever telemetry is on.
    pub health_action: HealthAction,
    /// RNG seed for data + init
    pub seed: u64,
    /// artifact directory
    pub artifacts_dir: String,
    /// output directory for metric CSVs
    pub out_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "lm_small".into(),
            optim: OptimConfig::default(),
            exec: ExecMode::Split,
            steps: 200,
            eval_every: 20,
            grad_accum: 1,
            workers: 1,
            step_threads: 1,
            state_dtype: StateDtype::F32,
            step_chunk: crate::optim::kernel::DEFAULT_CHUNK,
            comm_dtype: StateDtype::F32,
            comm_chunk: crate::comms::DEFAULT_COMM_CHUNK,
            comm_threads: 1,
            comm_buckets: crate::comms::DEFAULT_COMM_BUCKETS,
            comm_overlap: false,
            comm_transport: TransportKind::default(),
            kernel_backend: Backend::default(),
            pool: true,
            telemetry: false,
            telemetry_jsonl: None,
            trace_out: None,
            health_action: HealthAction::Warn,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
        }
    }
}

fn get_str(t: &TomlValue, key: &str, default: &str) -> String {
    t.get(key).and_then(TomlValue::as_str).map(String::from)
        .unwrap_or_else(|| default.to_string())
}

fn get_f64(t: &TomlValue, key: &str, default: f64) -> f64 {
    t.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
}

fn get_u64(t: &TomlValue, key: &str, default: u64) -> u64 {
    t.get(key).and_then(TomlValue::as_i64).map(|v| v as u64)
        .unwrap_or(default)
}

/// Numeric key that must error when present with a non-numeric value —
/// `clip_norm = "1.0"` must not silently run with clipping off. (The
/// new-in-PR-4 keys are strict; the legacy keys keep their lenient
/// defaulting for compatibility.)
fn strict_f64(t: &TomlValue, key: &str, section: &str)
              -> Result<Option<f64>> {
    match t.get(key) {
        None => Ok(None),
        Some(v) => match v.as_f64() {
            Some(x) => Ok(Some(x)),
            None => bail!("{section} {key} must be a number, got {v:?}"),
        },
    }
}

/// Levenshtein edit distance (for "did you mean" on unknown keys).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(),
                                          b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Reject unknown keys in `tbl`, naming the nearest valid key — a
/// `beta_1` typo must fail loudly instead of silently running with the
/// default (ISSUE 4 satellite).
fn reject_unknown_keys(tbl: &TomlValue, allowed: &[&str], section: &str)
                       -> Result<()> {
    let TomlValue::Table(m) = tbl else {
        return Ok(());
    };
    for key in m.keys() {
        if !allowed.contains(&key.as_str()) {
            let nearest = allowed
                .iter()
                .min_by_key(|a| edit_distance(key, a))
                .expect("allowlist is never empty");
            bail!("unknown key {key:?} in {section} — did you mean \
                   {nearest:?}? (valid keys: {allowed:?})");
        }
    }
    Ok(())
}

/// Keys accepted in `[optim]`.
const OPTIM_KEYS: &[&str] = &[
    "name", "lr", "beta1", "beta2", "eps", "schedule", "warmup_steps",
    "lr_eta0", "lr_alpha", "lr_tau", "clip_norm", "clip_value",
    "weight_decay", "group",
];

/// Keys accepted in `[train]`.
const TRAIN_KEYS: &[&str] = &[
    "model", "exec", "steps", "eval_every", "grad_accum", "workers",
    "step_threads", "state_dtype", "step_chunk", "comm_dtype", "comm_chunk",
    "comm_threads", "comm_buckets", "comm_overlap", "comm_transport",
    "kernel_backend", "pool", "telemetry", "telemetry_jsonl", "trace_out",
    "health_action", "seed", "artifacts_dir", "out_dir",
];

/// Keys accepted in each `[[optim.group]]`.
const GROUP_KEYS: &[&str] = &["pattern", "lr_scale", "weight_decay"];

/// Fetch a top-level section, erroring when it exists as anything but a
/// table — `[[optim]]` (array-of-tables) would otherwise make every
/// `get()` return `None` and silently run the whole section on defaults.
fn section_table(root: &TomlValue, key: &str) -> Result<TomlValue> {
    match root.get(key) {
        None => Ok(TomlValue::empty_table()),
        Some(t @ TomlValue::Table(_)) => Ok(t.clone()),
        Some(_) => bail!("[{key}] must be a table — did you write \
                          [[{key}]]? (double brackets declare an array \
                          of tables)"),
    }
}

/// Parse the `[[optim.group]]` array.
fn parse_groups(optim_tbl: &TomlValue) -> Result<Vec<GroupConfig>> {
    let Some(raw) = optim_tbl.get("group") else {
        return Ok(Vec::new());
    };
    let items = raw.as_array().ok_or_else(|| {
        anyhow::anyhow!("[optim] group must be an array of tables \
                         ([[optim.group]] sections)")
    })?;
    let mut groups = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        reject_unknown_keys(item, GROUP_KEYS,
                            &format!("[[optim.group]] #{}", i + 1))?;
        let pattern = item
            .get("pattern")
            .and_then(TomlValue::as_str)
            .ok_or_else(|| anyhow::anyhow!(
                "[[optim.group]] #{} needs a string `pattern`", i + 1))?
            .to_string();
        let section = format!("[[optim.group]] #{}", i + 1);
        groups.push(GroupConfig {
            pattern,
            lr_scale: strict_f64(item, "lr_scale", &section)?.unwrap_or(1.0),
            weight_decay: strict_f64(item, "weight_decay", &section)?,
        });
    }
    Ok(groups)
}

impl TrainConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let root = toml::parse(text).context("parsing config TOML")?;
        let d = TrainConfig::default();
        let od = OptimConfig::default();

        // unknown sections and keys are errors naming the nearest valid
        // key — a `beta_1` typo must not run with the default
        reject_unknown_keys(&root, &["optim", "train"], "the config root")?;
        let optim_tbl = section_table(&root, "optim")?;
        reject_unknown_keys(&optim_tbl, OPTIM_KEYS, "[optim]")?;
        let optim = OptimConfig {
            name: get_str(&optim_tbl, "name", &od.name),
            lr: get_f64(&optim_tbl, "lr", od.lr),
            beta1: get_f64(&optim_tbl, "beta1", od.beta1),
            beta2: get_f64(&optim_tbl, "beta2", od.beta2),
            eps: strict_f64(&optim_tbl, "eps", "[optim]")?.unwrap_or(od.eps),
            schedule: get_str(&optim_tbl, "schedule", &od.schedule),
            warmup_steps: get_u64(&optim_tbl, "warmup_steps", od.warmup_steps),
            lr_eta0: optim_tbl.get("lr_eta0").and_then(TomlValue::as_f64),
            lr_alpha: get_f64(&optim_tbl, "lr_alpha", od.lr_alpha),
            lr_tau: match optim_tbl.get("lr_tau").and_then(TomlValue::as_i64) {
                // reject instead of casting: -1 as u64 would wrap to a
                // huge stair width and "pass" the tau >= 1 check
                Some(v) if v < 1 => bail!("[optim] lr_tau must be >= 1, \
                                           got {v}"),
                Some(v) => Some(v as u64),
                None => None,
            },
            clip_norm: strict_f64(&optim_tbl, "clip_norm", "[optim]")?,
            clip_value: strict_f64(&optim_tbl, "clip_value", "[optim]")?,
            weight_decay: strict_f64(&optim_tbl, "weight_decay", "[optim]")?
                .unwrap_or(od.weight_decay),
            groups: parse_groups(&optim_tbl)?,
        };

        let train_tbl = section_table(&root, "train")?;
        reject_unknown_keys(&train_tbl, TRAIN_KEYS, "[train]")?;
        let cfg = Self {
            model: get_str(&train_tbl, "model", &d.model),
            exec: ExecMode::parse(&get_str(&train_tbl, "exec", "split"))?,
            steps: get_u64(&train_tbl, "steps", d.steps),
            eval_every: get_u64(&train_tbl, "eval_every", d.eval_every),
            grad_accum: get_u64(&train_tbl, "grad_accum", d.grad_accum),
            workers: get_u64(&train_tbl, "workers", d.workers as u64) as usize,
            step_threads: get_u64(&train_tbl, "step_threads",
                                  d.step_threads as u64) as usize,
            state_dtype: StateDtype::parse(&get_str(
                &train_tbl, "state_dtype", d.state_dtype.name()))
                .context("[train] state_dtype")?,
            step_chunk: match train_tbl.get("step_chunk")
                .and_then(TomlValue::as_i64)
            {
                // reject instead of casting: -64 as u64 would wrap to a
                // positive multiple of 64 and sail through check_chunk
                Some(v) if v < 1 => bail!("[train] step_chunk must be \
                                           >= 1, got {v}"),
                Some(v) => v as usize,
                None => d.step_chunk,
            },
            comm_dtype: StateDtype::parse(&get_str(
                &train_tbl, "comm_dtype", d.comm_dtype.name()))
                .context("[train] comm_dtype")?,
            comm_chunk: match train_tbl.get("comm_chunk")
                .and_then(TomlValue::as_i64)
            {
                // reject instead of casting: a negative would wrap
                // through `as u64` to a positive multiple of 64
                Some(v) if v < 1 => bail!("[train] comm_chunk must be \
                                           >= 1, got {v}"),
                Some(v) => v as usize,
                None => d.comm_chunk,
            },
            comm_threads: match train_tbl.get("comm_threads")
                .and_then(TomlValue::as_i64)
            {
                // reject instead of casting: -1 as u64 would wrap to a
                // huge thread count and sail past the > 0 check
                Some(v) if v < 1 => bail!("[train] comm_threads must be \
                                           >= 1, got {v}"),
                Some(v) => v as usize,
                None => d.comm_threads,
            },
            comm_buckets: match train_tbl.get("comm_buckets")
                .and_then(TomlValue::as_i64)
            {
                // reject instead of casting: a negative would wrap
                // through `as u64` to an absurd bucket count
                Some(v) if v < 1 => bail!("[train] comm_buckets must be \
                                           >= 1, got {v}"),
                Some(v) => v as usize,
                None => d.comm_buckets,
            },
            comm_overlap: match train_tbl.get("comm_overlap") {
                // strict: `comm_overlap = "on"` must error, not silently
                // run the serial pipeline
                None => d.comm_overlap,
                Some(v) => match v.as_bool() {
                    Some(b) => b,
                    None => bail!("[train] comm_overlap must be a \
                                   boolean, got {v:?}"),
                },
            },
            comm_transport: match train_tbl.get("comm_transport") {
                // no key: the ambient SM3_COMM_TRANSPORT decides, and a
                // typo'd env value must error, not silently run direct
                None => TransportKind::ambient()
                    .context("[train] comm_transport (SM3_COMM_TRANSPORT)")?,
                Some(v) => match v.as_str() {
                    Some(s) => TransportKind::parse(s)
                        .context("[train] comm_transport")?,
                    None => bail!("[train] comm_transport must be a \
                                   string, got {v:?}"),
                },
            },
            kernel_backend: Backend::parse(&get_str(
                &train_tbl, "kernel_backend", d.kernel_backend.name()))
                .context("[train] kernel_backend")?,
            pool: match train_tbl.get("pool") {
                // strict: `pool = "off"` must error, not silently keep
                // pooling (same contract as comm_overlap/telemetry)
                None => d.pool,
                Some(v) => match v.as_bool() {
                    Some(b) => b,
                    None => bail!("[train] pool must be a boolean, \
                                   got {v:?}"),
                },
            },
            telemetry: match train_tbl.get("telemetry") {
                // strict: `telemetry = "on"` must error, not silently
                // run unmeasured
                None => d.telemetry,
                Some(v) => match v.as_bool() {
                    Some(b) => b,
                    None => bail!("[train] telemetry must be a boolean, \
                                   got {v:?}"),
                },
            },
            telemetry_jsonl: match train_tbl.get("telemetry_jsonl") {
                None => d.telemetry_jsonl.clone(),
                Some(v) => match v.as_str() {
                    Some(s) => Some(s.to_string()),
                    None => bail!("[train] telemetry_jsonl must be a \
                                   string path, got {v:?}"),
                },
            },
            trace_out: match train_tbl.get("trace_out") {
                None => d.trace_out.clone(),
                Some(v) => match v.as_str() {
                    Some(s) => Some(s.to_string()),
                    None => bail!("[train] trace_out must be a string \
                                   path, got {v:?}"),
                },
            },
            health_action: match train_tbl.get("health_action") {
                None => d.health_action,
                Some(v) => match v.as_str() {
                    // strict like the other enum keys: a typo must
                    // error, not silently keep warning
                    Some(s) => s.parse().map_err(|e| {
                        anyhow::anyhow!("[train] {e}")
                    })?,
                    None => bail!("[train] health_action must be a string \
                                   (`warn` or `abort`), got {v:?}"),
                },
            },
            seed: get_u64(&train_tbl, "seed", d.seed),
            artifacts_dir: get_str(&train_tbl, "artifacts_dir",
                                   &d.artifacts_dir),
            out_dir: get_str(&train_tbl, "out_dir", &d.out_dir),
            optim,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_toml(&text)
    }

    pub fn validate(&self) -> Result<()> {
        if !crate::optim::ALL.contains(&self.optim.name.as_str()) {
            bail!("unknown optimizer {:?}", self.optim.name);
        }
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.grad_accum == 0 || self.workers == 0 {
            bail!("grad_accum and workers must be > 0");
        }
        if self.step_threads == 0 {
            bail!("step_threads must be > 0 (1 = serial)");
        }
        if self.step_threads > 1 && self.exec == ExecMode::Fused {
            bail!("step_threads applies to the split path only (the fused \
                   artifact already contains the optimizer)");
        }
        if self.state_dtype != StateDtype::F32 && self.exec == ExecMode::Fused {
            bail!("state_dtype = {:?} applies to the split path only (the \
                   fused artifact keeps its optimizer state in f32 device \
                   buffers)", self.state_dtype.name());
        }
        if self.kernel_backend != Backend::default()
            && self.exec == ExecMode::Fused
        {
            bail!("kernel_backend = {:?} applies to the split path only \
                   (the fused artifact contains its own kernels)",
                  self.kernel_backend.name());
        }
        crate::optim::kernel::check_chunk(self.step_chunk)
            .context("[train] step_chunk")?;
        if self.step_chunk != crate::optim::kernel::DEFAULT_CHUNK
            && self.exec == ExecMode::Fused
        {
            bail!("step_chunk applies to the split path only (the fused \
                   artifact already contains the optimizer)");
        }
        if self.comm_threads == 0 {
            bail!("comm_threads must be > 0 (1 = serial)");
        }
        if self.comm_buckets == 0 {
            bail!("comm_buckets must be > 0 (1 = monolithic exchange)");
        }
        crate::comms::check_comm_chunk(self.comm_chunk)
            .context("[train] comm_chunk")?;
        if self.exec == ExecMode::Fused {
            // the fused artifact runs single-worker with no gradient
            // exchange; reject comm knobs it would silently ignore
            if self.comm_dtype != StateDtype::F32 {
                bail!("comm_dtype = {:?} applies to the split path only \
                       (the fused artifact has no gradient exchange)",
                      self.comm_dtype.name());
            }
            if self.comm_threads > 1 {
                bail!("comm_threads applies to the split path only (the \
                       fused artifact has no gradient exchange)");
            }
            if self.comm_chunk != crate::comms::DEFAULT_COMM_CHUNK {
                bail!("comm_chunk applies to the split path only (the \
                       fused artifact has no gradient exchange)");
            }
            if self.comm_buckets != crate::comms::DEFAULT_COMM_BUCKETS {
                bail!("comm_buckets applies to the split path only (the \
                       fused artifact has no gradient exchange)");
            }
            if self.comm_overlap {
                bail!("comm_overlap applies to the split path only (the \
                       fused artifact has no gradient exchange)");
            }
            // comm_transport is deliberately NOT rejected on the fused
            // path: its default tracks the ambient SM3_COMM_TRANSPORT
            // (a CI matrix dimension), and with no exchange the knob is
            // inert rather than silently wrong
        }
        if self.telemetry_jsonl.is_some() && !self.telemetry {
            bail!("[train] telemetry_jsonl requires telemetry = true \
                   (the event stream is fed by the telemetry cells)");
        }
        if self.trace_out.is_some() && !self.telemetry {
            bail!("[train] trace_out requires telemetry = true (the \
                   trace rings record the telemetry spans)");
        }
        if self.telemetry && self.exec == ExecMode::Fused {
            // the fused artifact exposes no phase seams to instrument;
            // reject rather than emit all-zero phase columns
            bail!("telemetry applies to the split path only (the fused \
                   artifact has no grad/comm/opt phase boundaries)");
        }
        if !(0.0..1.0).contains(&self.optim.beta1) {
            bail!("beta1 out of range");
        }
        if self.optim.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if !(self.optim.eps.is_finite() && self.optim.eps > 0.0) {
            bail!("[optim] eps must be finite and > 0, got {}",
                  self.optim.eps);
        }
        if self.optim.eps != DEFAULT_EPS
            && !crate::optim::Method::from_name(&self.optim.name)?.has_eps()
        {
            // same fail-loudly rule as the fused-path checks below: a
            // knob Method::set_eps would silently drop is a config error
            bail!("[optim] eps applies to Adam only ({:?} has no eps)",
                  self.optim.name);
        }
        if self.exec == ExecMode::Fused {
            // the fused artifact bakes its own hyperparameters and has no
            // update-pipeline seam; reject knobs it would silently ignore
            if self.optim.eps != DEFAULT_EPS {
                bail!("[optim] eps applies to the split path only (the \
                       fused artifact bakes its own eps)");
            }
            if self.optim.has_transforms() {
                bail!("[optim] clip_norm / clip_value / weight_decay / \
                       group apply to the split path only (the fused \
                       artifact contains the optimizer)");
            }
        }
        if !matches!(self.optim.schedule.as_str(),
                     "paper" | "constant" | "rsqrt" | "linear" | "staircase")
        {
            bail!("unknown schedule {:?} (paper|constant|rsqrt|linear|\
                   staircase)", self.optim.schedule);
        }
        // staircase parameters: validated here so a bad config fails at
        // parse time, not mid-run (resolve re-checks at schedule build)
        self.optim.staircase_params()
            .resolve(self.optim.lr, self.steps)
            .context("[optim] lr_eta0 / lr_alpha / lr_tau")?;
        // hyperparameters, transforms, and groups: assemble the OptimSpec
        // so eps > 0, clip > 0, wd >= 0, lr_scale > 0 etc. fail at config
        // parse time with the builder's own messages (group-vs-parameter
        // matching needs the model's leaf names and happens at build)
        self.optim_spec().context("[optim]")?;
        Ok(())
    }

    /// Assemble the composable construction spec (`optim::OptimSpec`,
    /// DESIGN.md §11) this config describes: typed method
    /// hyperparameters, state-storage options, transform stages in
    /// canonical order (`clip_value` → `clip_norm` → `weight_decay`),
    /// param groups, and the sharding plan. The trainer builds the
    /// split-path optimizer from exactly this.
    pub fn optim_spec(&self) -> Result<OptimSpec> {
        let mut spec = OptimSpec::named(&self.optim.name)?
            .beta1(self.optim.beta1 as f32)
            .beta2(self.optim.beta2 as f32)
            .eps(self.optim.eps as f32)
            .state_dtype(self.state_dtype)
            .step_chunk(self.step_chunk)
            .threads(self.step_threads)
            .kernel_backend(self.kernel_backend)
            .split_policy(SplitPolicy::IntraLeaf);
        if let Some(c) = self.optim.clip_value {
            spec = spec.clip_by_value(c as f32);
        }
        if let Some(c) = self.optim.clip_norm {
            spec = spec.clip_by_global_norm(c as f32);
        }
        if self.optim.weight_decay != 0.0 {
            spec = spec.weight_decay(self.optim.weight_decay as f32);
        }
        for g in &self.optim.groups {
            let mut gs = GroupSpec::new(g.pattern.clone())
                .lr_scale(g.lr_scale as f32);
            if let Some(wd) = g.weight_decay {
                gs = gs.weight_decay(wd as f32);
            }
            spec = spec.group(gs);
        }
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.model, "lm_small");
        assert_eq!(cfg.optim.name, "sm3");
        assert_eq!(cfg.exec, ExecMode::Split);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
[train]
model = "mt_small"
exec = "fused"
steps = 500
eval_every = 50
grad_accum = 2
workers = 4
seed = 7

[optim]
name = "adafactor"
lr = 0.00045
beta1 = 0.9
beta2 = 0.98
schedule = "rsqrt"
warmup_steps = 40
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, "mt_small");
        assert_eq!(cfg.exec, ExecMode::Fused);
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.optim.name, "adafactor");
        assert!((cfg.optim.lr - 0.00045).abs() < 1e-12);
        assert_eq!(cfg.optim.schedule, "rsqrt");
    }

    #[test]
    fn step_threads_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.step_threads, 1);
        let cfg =
            TrainConfig::from_toml("[train]\nstep_threads = 4\n").unwrap();
        assert_eq!(cfg.step_threads, 4);
        assert!(TrainConfig::from_toml("[train]\nstep_threads = 0\n").is_err());
        // sharded stepping is a split-path feature; fused must reject it
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstep_threads = 4\n").is_err());
    }

    #[test]
    fn state_dtype_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::F32);
        let cfg =
            TrainConfig::from_toml("[train]\nstate_dtype = \"q8\"\n").unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::Q8);
        let cfg =
            TrainConfig::from_toml("[train]\nstate_dtype = \"bf16\"\n")
                .unwrap();
        assert_eq!(cfg.state_dtype, StateDtype::Bf16);
        // unknown dtype names must fail with a message, not default
        assert!(TrainConfig::from_toml(
            "[train]\nstate_dtype = \"fp8\"\n").is_err());
        // quantized state is a split-path feature; fused must reject it
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstate_dtype = \"q8\"\n").is_err());
        // fused + explicit f32 is fine (it is the fused behavior anyway)
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstate_dtype = \"f32\"\n").is_ok());
        // quantized state composes with sharded stepping
        let cfg = TrainConfig::from_toml(
            "[train]\nstep_threads = 4\nstate_dtype = \"q8\"\n").unwrap();
        assert_eq!((cfg.step_threads, cfg.state_dtype),
                   (4, StateDtype::Q8));
    }

    #[test]
    fn step_chunk_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.step_chunk, crate::optim::kernel::DEFAULT_CHUNK);
        let cfg =
            TrainConfig::from_toml("[train]\nstep_chunk = 128\n").unwrap();
        assert_eq!(cfg.step_chunk, 128);
        // must be a positive multiple of the q8 block; negatives must
        // error rather than wrap through `as u64` (−64 would wrap to a
        // huge multiple of 64)
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = 0\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = 100\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nstep_chunk = -64\n").is_err());
        // split-path knob: fused rejects a non-default tile
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\nstep_chunk = 128\n").is_err());
        // composes with sharding and quantized state
        let cfg = TrainConfig::from_toml(
            "[train]\nstep_threads = 4\nstate_dtype = \"q8\"\n\
             step_chunk = 256\n").unwrap();
        assert_eq!((cfg.step_threads, cfg.state_dtype, cfg.step_chunk),
                   (4, StateDtype::Q8, 256));
    }

    /// ISSUE 5 tentpole: the comm knobs parse, default, validate, and
    /// are fused-path-rejected like the step knobs.
    #[test]
    fn comm_knobs_parse_defaults_and_validate() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.comm_dtype, StateDtype::F32);
        assert_eq!(cfg.comm_chunk, crate::comms::DEFAULT_COMM_CHUNK);
        assert_eq!(cfg.comm_threads, 1);
        let cfg = TrainConfig::from_toml(
            "[train]\nworkers = 4\ncomm_dtype = \"q8\"\ncomm_chunk = 128\n\
             comm_threads = 4\n").unwrap();
        assert_eq!((cfg.comm_dtype, cfg.comm_chunk, cfg.comm_threads),
                   (StateDtype::Q8, 128, 4));
        // unknown dtype names must fail with a message, not default
        assert!(TrainConfig::from_toml(
            "[train]\ncomm_dtype = \"fp8\"\n").is_err());
        // comm_chunk: positive multiple of 64, no negative wrapping
        assert!(TrainConfig::from_toml("[train]\ncomm_chunk = 0\n").is_err());
        assert!(TrainConfig::from_toml("[train]\ncomm_chunk = 100\n")
            .is_err());
        assert!(TrainConfig::from_toml("[train]\ncomm_chunk = -64\n")
            .is_err());
        assert!(TrainConfig::from_toml("[train]\ncomm_threads = 0\n")
            .is_err());
        // negative comm_threads must error, not wrap through `as u64`
        assert!(TrainConfig::from_toml("[train]\ncomm_threads = -1\n")
            .is_err());
        // split-path knobs: the fused artifact has no gradient exchange
        for bad in ["comm_dtype = \"q8\"", "comm_threads = 4",
                    "comm_chunk = 128"] {
            let toml = format!("[train]\nexec = \"fused\"\n{bad}\n");
            assert!(TrainConfig::from_toml(&toml).is_err(), "{bad}");
        }
        // fused + explicit defaults is fine
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\ncomm_dtype = \"f32\"\n\
             comm_threads = 1\n").is_ok());
        // a typo'd comm key names the nearest valid one
        let err = TrainConfig::from_toml("[train]\ncomm_dtpye = \"q8\"\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("comm_dtpye") && msg.contains("comm_dtype"),
                "{msg}");
    }

    /// ISSUE 8: the overlap-pipeline knobs parse, default, and validate
    /// like the other comm knobs (bucket count strict-positive, overlap
    /// strict-boolean, transport from the registry or the ambient env).
    #[test]
    fn overlap_knobs_parse_defaults_and_validate() {
        use crate::comms::TransportKind;
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.comm_buckets, crate::comms::DEFAULT_COMM_BUCKETS);
        assert!(!cfg.comm_overlap);
        // the no-key default tracks the ambient SM3_COMM_TRANSPORT (a CI
        // matrix dimension), so compare against it rather than Direct
        assert_eq!(cfg.comm_transport, TransportKind::ambient().unwrap());
        let cfg = TrainConfig::from_toml(
            "[train]\nworkers = 4\ncomm_buckets = 8\ncomm_overlap = true\n\
             comm_transport = \"inproc\"\n").unwrap();
        assert_eq!((cfg.comm_buckets, cfg.comm_overlap, cfg.comm_transport),
                   (8, true, TransportKind::Inproc));
        let cfg = TrainConfig::from_toml(
            "[train]\ncomm_transport = \"direct\"\n").unwrap();
        assert_eq!(cfg.comm_transport, TransportKind::Direct);
        // comm_buckets: strict positive integer, no negative wrapping
        assert!(TrainConfig::from_toml("[train]\ncomm_buckets = 0\n")
            .is_err());
        assert!(TrainConfig::from_toml("[train]\ncomm_buckets = -2\n")
            .is_err());
        // comm_overlap: strict boolean — "on" must error, not default
        assert!(TrainConfig::from_toml(
            "[train]\ncomm_overlap = \"on\"\n").is_err());
        // unknown transport names must fail with a message, not default
        let err = TrainConfig::from_toml(
            "[train]\ncomm_transport = \"rdma\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("rdma"), "{err:#}");
        // split-path knobs: the fused artifact has no gradient exchange
        for bad in ["comm_buckets = 2", "comm_overlap = true"] {
            let toml = format!("[train]\nexec = \"fused\"\n{bad}\n");
            assert!(TrainConfig::from_toml(&toml).is_err(), "{bad}");
        }
        // fused + explicit defaults is fine (comm_transport stays inert)
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\ncomm_buckets = 1\n\
             comm_overlap = false\n").is_ok());
        // a typo'd key names the nearest valid one
        let err = TrainConfig::from_toml("[train]\ncomm_bukets = 2\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("comm_bukets") && msg.contains("comm_buckets"),
                "{msg}");
    }

    /// ISSUE 9: the memory-pool knob defaults on, parses strictly, and
    /// a typo'd key names it.
    #[test]
    fn pool_knob_parses_strictly_and_defaults_on() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert!(cfg.pool, "pool must default on");
        let cfg =
            TrainConfig::from_toml("[train]\npool = false\n").unwrap();
        assert!(!cfg.pool);
        // strict boolean — `pool = "off"` must error, not silently pool
        assert!(TrainConfig::from_toml("[train]\npool = \"off\"\n")
            .is_err());
        let err =
            TrainConfig::from_toml("[train]\npol = true\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pol") && msg.contains("pool"), "{msg}");
    }

    /// ISSUE 6 tentpole: the kernel backend parses, defaults to the
    /// feature-selected backend, and is fused-path-rejected like the
    /// other split knobs.
    #[test]
    fn kernel_backend_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.kernel_backend, Backend::default());
        let cfg = TrainConfig::from_toml(
            "[train]\nkernel_backend = \"simd\"\n").unwrap();
        assert_eq!(cfg.kernel_backend, Backend::Simd);
        let cfg = TrainConfig::from_toml(
            "[train]\nkernel_backend = \"scalar\"\n").unwrap();
        assert_eq!(cfg.kernel_backend, Backend::Scalar);
        // unknown backend names must fail with a message, not default
        assert!(TrainConfig::from_toml(
            "[train]\nkernel_backend = \"avx512\"\n").is_err());
        // split-path knob: fused rejects a non-default backend, but
        // accepts the explicit default (whichever the feature picked)
        let other = Backend::ALL.iter().copied()
            .find(|b| *b != Backend::default()).unwrap();
        let toml = format!(
            "[train]\nexec = \"fused\"\nkernel_backend = \"{}\"\n",
            other.name());
        assert!(TrainConfig::from_toml(&toml).is_err());
        let toml = format!(
            "[train]\nexec = \"fused\"\nkernel_backend = \"{}\"\n",
            Backend::default().name());
        assert!(TrainConfig::from_toml(&toml).is_ok());
        // composes with the other split-path knobs
        let cfg = TrainConfig::from_toml(
            "[train]\nstep_threads = 4\nstate_dtype = \"q8\"\n\
             kernel_backend = \"simd\"\n").unwrap();
        assert_eq!((cfg.step_threads, cfg.state_dtype, cfg.kernel_backend),
                   (4, StateDtype::Q8, Backend::Simd));
        // a typo'd key names the nearest valid one
        let err = TrainConfig::from_toml(
            "[train]\nkernel_backened = \"simd\"\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("kernel_backened")
                    && msg.contains("kernel_backend"),
                "{msg}");
    }

    /// ISSUE 7 tentpole: the telemetry knobs parse, default off,
    /// validate, and are fused-path-rejected like the other split knobs.
    #[test]
    fn telemetry_knobs_parse_defaults_and_validate() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert!(!cfg.telemetry);
        assert_eq!(cfg.telemetry_jsonl, None);
        let cfg = TrainConfig::from_toml(
            "[train]\ntelemetry = true\n\
             telemetry_jsonl = \"out/events.jsonl\"\n").unwrap();
        assert!(cfg.telemetry);
        assert_eq!(cfg.telemetry_jsonl.as_deref(), Some("out/events.jsonl"));
        // strict typing: a wrong-typed value errors, never defaults
        assert!(TrainConfig::from_toml(
            "[train]\ntelemetry = \"on\"\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\ntelemetry = 1\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\ntelemetry = true\ntelemetry_jsonl = 7\n").is_err());
        // the event stream needs the cells recording
        let err = TrainConfig::from_toml(
            "[train]\ntelemetry_jsonl = \"out/e.jsonl\"\n").unwrap_err();
        assert!(err.to_string().contains("requires telemetry"), "{err}");
        // split-path knob: the fused artifact has no phase seams
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\ntelemetry = true\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\ntelemetry = false\n").is_ok());
        // composes with the other split-path knobs
        let cfg = TrainConfig::from_toml(
            "[train]\ntelemetry = true\nworkers = 4\nstep_threads = 2\n\
             comm_dtype = \"q8\"\nstate_dtype = \"q8\"\n").unwrap();
        assert!(cfg.telemetry);
        // a typo'd key names the nearest valid one
        let err = TrainConfig::from_toml(
            "[train]\ntelemetyr = true\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("telemetyr") && msg.contains("telemetry"),
                "{msg}");
        let err = TrainConfig::from_toml(
            "[train]\ntelemetry_json = \"x\"\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("telemetry_json")
                    && msg.contains("telemetry_jsonl"),
                "{msg}");
    }

    /// ISSUE 10: the trace/health knobs parse, default off/warn, and
    /// validate (trace rings record telemetry spans, so `trace_out`
    /// requires the cells on).
    #[test]
    fn trace_and_health_knobs_parse_defaults_and_validate() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.trace_out, None);
        assert_eq!(cfg.health_action, HealthAction::Warn);
        let cfg = TrainConfig::from_toml(
            "[train]\ntelemetry = true\ntrace_out = \"out/trace.json\"\n\
             health_action = \"abort\"\n").unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("out/trace.json"));
        assert_eq!(cfg.health_action, HealthAction::Abort);
        // trace rings record the telemetry spans
        let err = TrainConfig::from_toml(
            "[train]\ntrace_out = \"t.json\"\n").unwrap_err();
        assert!(err.to_string().contains("requires telemetry"), "{err}");
        // strict types and values
        assert!(TrainConfig::from_toml(
            "[train]\ntelemetry = true\ntrace_out = 7\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\nhealth_action = \"panic\"\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\nhealth_action = true\n").is_err());
        // health_action is legal without telemetry (the rules just see
        // loss-only observations)
        assert!(TrainConfig::from_toml(
            "[train]\nhealth_action = \"abort\"\n").is_ok());
    }

    /// ISSUE 3 satellite: the staircase schedule's η₀/α/τ come from the
    /// config (defaults preserved), and α is range-checked at parse time.
    #[test]
    fn staircase_lr_params_parse_and_validate() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.optim.lr_eta0, None);
        assert_eq!(cfg.optim.lr_alpha, 0.88);
        assert_eq!(cfg.optim.lr_tau, None);
        let cfg = TrainConfig::from_toml(
            "[optim]\nschedule = \"staircase\"\nlr_eta0 = 0.003\n\
             lr_alpha = 0.5\nlr_tau = 400\n").unwrap();
        assert_eq!(cfg.optim.lr_eta0, Some(0.003));
        assert_eq!(cfg.optim.lr_alpha, 0.5);
        assert_eq!(cfg.optim.lr_tau, Some(400));
        let p = cfg.optim.staircase_params();
        assert_eq!(p.resolve(cfg.optim.lr, cfg.steps).unwrap(),
                   (0.003, 0.5, 400));
        // 0 < alpha < 1 enforced at config parse, any schedule
        assert!(TrainConfig::from_toml("[optim]\nlr_alpha = 1.0\n").is_err());
        assert!(TrainConfig::from_toml("[optim]\nlr_alpha = 0.0\n").is_err());
        assert!(TrainConfig::from_toml(
            "[optim]\nschedule = \"staircase\"\nlr_alpha = 2.0\n").is_err());
        // unknown schedule names now fail instead of silently falling
        // back to constant
        assert!(TrainConfig::from_toml(
            "[optim]\nschedule = \"cosine\"\n").is_err());
        // negative lr_tau must error, not wrap through `as u64`
        assert!(TrainConfig::from_toml("[optim]\nlr_tau = -1\n").is_err());
        assert!(TrainConfig::from_toml("[optim]\nlr_tau = 0\n").is_err());
    }

    /// ISSUE 4 satellite: Adam's eps is a config knob (default
    /// preserved, validated > 0, split-path only).
    #[test]
    fn eps_parses_defaults_and_validates() {
        let cfg = TrainConfig::from_toml("").unwrap();
        assert_eq!(cfg.optim.eps, DEFAULT_EPS);
        let cfg = TrainConfig::from_toml(
            "[optim]\nname = \"adam\"\neps = 1e-6\n").unwrap();
        assert_eq!(cfg.optim.eps, 1e-6);
        assert!(TrainConfig::from_toml(
            "[optim]\nname = \"adam\"\neps = 0.0\n").is_err());
        assert!(TrainConfig::from_toml(
            "[optim]\nname = \"adam\"\neps = -1e-8\n").is_err());
        // a non-default eps on an eps-less method is silently ignored by
        // the update rule, so it must be a config error (fail loudly)
        let err = TrainConfig::from_toml(
            "[optim]\nname = \"sm3\"\neps = 1e-6\n").unwrap_err();
        assert!(err.to_string().contains("Adam only"), "{err}");
        assert!(TrainConfig::from_toml(
            "[optim]\nname = \"sm3\"\neps = 1e-8\n").is_ok());
        // split-path knob: fused rejects a non-default eps
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\n[optim]\nname = \"adam\"\n\
             eps = 1e-6\n").is_err());
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\n[optim]\nname = \"adam\"\n\
             eps = 1e-8\n").is_ok());
    }

    /// ISSUE 4 satellite: unknown keys in [optim]/[train] are rejected
    /// with the nearest valid key named — a `beta_1` typo must not run
    /// silently with the default.
    #[test]
    fn unknown_keys_rejected_with_suggestion() {
        let err =
            TrainConfig::from_toml("[optim]\nbeta_1 = 0.95\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("beta_1") && msg.contains("beta1"), "{msg}");
        let err = TrainConfig::from_toml("[train]\nstep_thread = 4\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("step_thread") && msg.contains("step_threads"),
                "{msg}");
        // unknown sections too
        let err = TrainConfig::from_toml("[optimizer]\nlr = 0.1\n")
            .unwrap_err();
        assert!(err.to_string().contains("optim"), "{err}");
        // and unknown keys inside [[optim.group]]
        let err = TrainConfig::from_toml(
            "[[optim.group]]\npattern = \"b\"\nlr_scal = 0.5\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("lr_scal") && msg.contains("lr_scale"),
                "{msg}");
        // [[optim]] / [[train]] (array-of-tables typo) must error, not
        // silently run the whole section on defaults
        let err = TrainConfig::from_toml(
            "[[optim]]\nname = \"adam\"\nlr = 0.5\n").unwrap_err();
        assert!(err.to_string().contains("array of tables"), "{err}");
        assert!(TrainConfig::from_toml("[[train]]\nsteps = 5\n").is_err());
    }

    /// Transforms parse, validate, and are fused-path-rejected; the
    /// config assembles an OptimSpec the trainer can build from.
    #[test]
    fn transform_keys_parse_and_validate() {
        let cfg = TrainConfig::from_toml(
            "[optim]\nname = \"adam\"\nclip_norm = 1.0\nclip_value = 0.5\n\
             weight_decay = 0.01\n\n[[optim.group]]\npattern = \"*bias*\"\n\
             weight_decay = 0.0\n\n[[optim.group]]\npattern = \"embed\"\n\
             lr_scale = 0.5\n").unwrap();
        assert_eq!(cfg.optim.clip_norm, Some(1.0));
        assert_eq!(cfg.optim.clip_value, Some(0.5));
        assert_eq!(cfg.optim.weight_decay, 0.01);
        assert_eq!(cfg.optim.groups.len(), 2);
        assert_eq!(cfg.optim.groups[0],
                   GroupConfig { pattern: "*bias*".into(), lr_scale: 1.0,
                                 weight_decay: Some(0.0) });
        assert_eq!(cfg.optim.groups[1].lr_scale, 0.5);
        let spec = cfg.optim_spec().unwrap();
        let specs = vec![crate::optim::ParamSpec::new("embed", &[10, 4]),
                         crate::optim::ParamSpec::new("l0/bias", &[4])];
        let opt = spec.build(&specs).unwrap();
        assert_eq!(opt.name(), "adam");
        // bad values fail at parse time
        assert!(TrainConfig::from_toml("[optim]\nclip_norm = 0.0\n")
            .is_err());
        assert!(TrainConfig::from_toml("[optim]\nweight_decay = -0.1\n")
            .is_err());
        assert!(TrainConfig::from_toml(
            "[[optim.group]]\nlr_scale = 0.5\n").is_err(),
            "group without pattern must fail");
        assert!(TrainConfig::from_toml(
            "[[optim.group]]\npattern = \"b\"\nlr_scale = 0.0\n").is_err());
        // split-path only
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\n[optim]\nclip_norm = 1.0\n")
            .is_err());
        assert!(TrainConfig::from_toml(
            "[train]\nexec = \"fused\"\n[optim]\nweight_decay = 0.01\n")
            .is_err());
    }

    /// The new keys are strictly typed: a wrong-typed value must error,
    /// not silently run with the feature off or the default.
    #[test]
    fn wrong_typed_transform_keys_are_rejected() {
        for bad in ["clip_norm = \"1.0\"", "clip_norm = true",
                    "clip_value = \"x\"", "weight_decay = \"0.01\"",
                    "eps = \"1e-6\""] {
            let toml = format!("[optim]\nname = \"adam\"\n{bad}\n");
            let err = TrainConfig::from_toml(&toml).unwrap_err();
            assert!(err.to_string().contains("must be a number"),
                    "{bad}: {err}");
        }
        let err = TrainConfig::from_toml(
            "[[optim.group]]\npattern = \"b\"\nlr_scale = \"0.5\"\n")
            .unwrap_err();
        assert!(err.to_string().contains("must be a number"), "{err}");
        // integer literals still coerce (as_f64 accepts both)
        let cfg = TrainConfig::from_toml(
            "[optim]\nname = \"adam\"\nclip_norm = 1\n").unwrap();
        assert_eq!(cfg.optim.clip_norm, Some(1.0));
    }

    #[test]
    fn edit_distance_sanity() {
        assert_eq!(edit_distance("beta_1", "beta1"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn rejects_bad_optimizer() {
        assert!(TrainConfig::from_toml("[optim]\nname = \"zzz\"\n").is_err());
    }

    #[test]
    fn rejects_zero_steps() {
        assert!(TrainConfig::from_toml("[train]\nsteps = 0\n").is_err());
    }

    #[test]
    fn rejects_bad_exec_mode() {
        assert!(TrainConfig::from_toml("[train]\nexec = \"warp\"\n").is_err());
    }
}
