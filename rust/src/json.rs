//! Minimal JSON parser and serializer (serde is unavailable offline).
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and serializes telemetry exports (`BENCH_*.json`, JSONL event
//! streams — see `telemetry::jsonl`). Full JSON value model,
//! recursive-descent parser, no external deps. Numbers are f64; the
//! manifest only uses integers within f64 range, and `Display` prints
//! integral values without a fraction so counters round-trip exactly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|m| m.get(key))
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Compact (single-line) serialization; output re-parses to an equal
/// value. Object keys keep `BTreeMap` order, so serialization is
/// deterministic. Non-finite numbers (which valid parses never produce)
/// serialize as `null`.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Number(n) => {
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    // integral and exactly representable: no fraction,
                    // so u64-derived counters round-trip bit-exactly
                    write!(f, "{}", *n as i64)
                } else {
                    // Rust's f64 Display is shortest-round-trip
                    write!(f, "{n}")
                }
            }
            Json::String(s) => write_escaped(f, s),
            Json::Array(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Object(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()
                                .ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code)
                            .ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        let s = std::str::from_utf8(
                            &self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Number(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Number(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(),
                   Json::String("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_manifest_shape_entries() {
        let v = Json::parse(
            r#"{"name": "params/embed", "shape": [64, 32], "dtype": "f32"}"#)
            .unwrap();
        let shape: Vec<usize> = v.get("shape").unwrap().as_array().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![64, 32]);
    }

    #[test]
    fn display_round_trips() {
        let cases = [
            "null",
            "true",
            r#"{"a":[1,2.5,{"b":"c"}],"d":{},"e":-150,"f":"x\ny \"q\""}"#,
            r#"[0,9007199254740992,1e300,"héllo → ok",""]"#,
        ];
        for text in cases {
            let v = Json::parse(text).unwrap();
            let printed = v.to_string();
            let reparsed = Json::parse(&printed).unwrap();
            assert_eq!(reparsed, v, "round-trip failed for {text}");
        }
    }

    #[test]
    fn display_prints_integers_without_fraction() {
        assert_eq!(Json::Number(42.0).to_string(), "42");
        assert_eq!(Json::Number(-3.0).to_string(), "-3");
        assert_eq!(Json::Number(0.25).to_string(), "0.25");
        assert_eq!(Json::Number(f64::NAN).to_string(), "null");
        let mut m = BTreeMap::new();
        m.insert("k".to_string(), Json::Array(vec![Json::Bool(false)]));
        assert_eq!(Json::Object(m).to_string(), r#"{"k":[false]}"#);
    }

    #[test]
    fn unicode_strings() {
        assert_eq!(Json::parse(r#""héllo → ok""#).unwrap(),
                   Json::String("héllo → ok".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(),
                   Json::String("A".into()));
    }
}
