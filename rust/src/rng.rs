//! Deterministic random number generation.
//!
//! The registry is offline, so `rand` is unavailable; this module provides
//! the small, well-known generators the framework needs: SplitMix64 for
//! seeding, xoshiro256++ for the main stream, Box–Muller normals, and a
//! Zipf sampler for the synthetic corpora. Everything is reproducible from
//! a single `u64` seed, which the experiment harness logs.

/// SplitMix64 — used to expand a user seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi exclusive, hi > lo.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with N(mean, std) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(s) sampler over ranks 0..n via precomputed CDF inversion.
/// Natural-language token frequencies are approximately Zipfian, which is
/// what produces the row activation patterns in embedding gradients that
/// SM3's cover exploits (paper §4, Fig. 1).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
            cdf.push(total);
        }
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Sample a rank in [0, n); rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let i = rng.range(-5, 5);
            assert!((-5..5).contains(&i));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let zipf = Zipf::new(100, 1.2);
        let mut rng = Rng::new(11);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
