//! Quickstart: the 60-second tour of the public API.
//!
//! Builds an optimizer through the composable `OptimSpec` API (clipping,
//! decoupled weight decay, param groups), loads the artifact manifest,
//! trains the tiny LM with SM3 on both execution paths, shows they
//! agree, and prints the memory accounting that motivates the paper.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::memory::{inventory, opt_state_bytes, opt_state_floats,
                  TRANSFORM_STATE_FLOATS};
use sm3::optim::{GroupSpec, OptimSpec, ParamSpec, StateDtype};
use sm3::runtime::Runtime;
use std::sync::Arc;

fn main() -> Result<()> {
    // 0. The construction API (DESIGN.md §11): a typed spec composes the
    //    method, state storage, update transforms, and param groups; no
    //    artifacts needed. The same grammar reaches TOML configs as
    //    `[optim] clip_norm / weight_decay` + `[[optim.group]]` tables.
    //    (Built here on a miniature spec — the static accountant below
    //    gives the model-scale numbers without allocating any state.)
    let demo = vec![ParamSpec::new("embed", &[512, 64]),
                    ParamSpec::new("ln_bias", &[64])];
    let opt = OptimSpec::named("sm3")?
        .state_dtype(StateDtype::Q8)
        .clip_by_global_norm(1.0)
        .weight_decay(0.01)
        .group(GroupSpec::new("*bias*").weight_decay(0.0))
        .threads(4)
        .build(&demo)?;
    println!(
        "OptimSpec: {} + clip(1.0) + decay(0.01), q8 state, 4 threads — \
         {} state floats / {} bytes on the demo spec",
        opt.name(), opt.state_floats(), opt.state_bytes()
    );
    drop(opt);
    // Model scale, from the static accountant (no allocation): the
    // transform pipeline adds exactly TRANSFORM_STATE_FLOATS scalars.
    let big = inventory::transformer_big();
    println!(
        "  Transformer-Big sm3 @ q8 would hold {:.1} MiB of state \
         (+{TRANSFORM_STATE_FLOATS} pipeline scalars)",
        opt_state_bytes("sm3", &big, StateDtype::Q8)? as f64
            / (1024.0 * 1024.0)
    );

    // 1. A runtime over the AOT artifacts (PJRT CPU client + manifest).
    let runtime = Arc::new(Runtime::new("artifacts")?);
    println!("platform: {}", runtime.platform());
    println!("models in manifest: {:?}",
             runtime.manifest.models.keys().collect::<Vec<_>>());

    // 2. Configure a run: tiny LM, SM3 optimizer, split execution path
    //    (grad artifact + Rust optimizer bank).
    let mut cfg = TrainConfig::default();
    cfg.model = "lm_tiny".into();
    cfg.optim.name = "sm3".into();
    cfg.optim.lr = 0.3;
    cfg.optim.warmup_steps = 10;
    cfg.steps = 50;
    cfg.eval_every = 25;
    cfg.exec = ExecMode::Split;

    let mut trainer = Trainer::with_runtime(cfg.clone(), runtime.clone())?;
    let hist = trainer.train()?;
    println!("\nsplit path:  loss {:.3} -> {:.3}",
             hist.steps.first().unwrap().loss,
             hist.steps.last().unwrap().loss);

    // 3. Same run on the fused path (the SM3 Pallas kernel inside the HLO
    //    artifact). The loss trajectory must match the Rust optimizer's.
    cfg.exec = ExecMode::Fused;
    let mut fused = Trainer::with_runtime(cfg, runtime)?;
    let fhist = fused.train()?;
    println!("fused path:  loss {:.3} -> {:.3}",
             fhist.steps.first().unwrap().loss,
             fhist.steps.last().unwrap().loss);
    let max_dev = hist
        .steps
        .iter()
        .zip(&fhist.steps)
        .map(|(a, b)| (a.loss - b.loss).abs())
        .fold(0.0, f64::max);
    println!("max per-step loss deviation: {max_dev:.2e}");
    assert!(max_dev < 1e-4, "paths diverged");

    // 4. The paper's point, in two lines: optimizer state for the real
    //    Transformer-Big under Adam vs SM3.
    let big = inventory::transformer_big();
    let d: usize = big.iter().map(|s| s.numel()).sum();
    let adam = opt_state_floats("adam", &big)?;
    let sm3 = opt_state_floats("sm3", &big)?;
    println!("\nTransformer-Big optimizer state: adam {:.1}M floats, \
              sm3 {:.1}M floats",
             adam as f64 / 1e6, sm3 as f64 / 1e6);
    println!("second-moment statistics alone: adam {:.1}M -> sm3 {:.2}M \
              ({:.0}x smaller — \"virtually eliminated\")",
             (adam - d) as f64 / 1e6, (sm3 - d) as f64 / 1e6,
             (adam - d) as f64 / (sm3 - d) as f64);
    Ok(())
}
