//! Translation workload (the paper's §5.1 scenario at miniature scale):
//! train the seq2seq transformer on the synthetic parallel corpus with a
//! chosen optimizer, report log-perplexity and corpus BLEU.
//!
//! Run: `cargo run --release --example translation -- [optimizer] [steps]`
//! e.g. `... -- sm3 200`, `... -- adafactor 200`

use anyhow::Result;
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;

fn main() -> Result<()> {
    let opt = std::env::args().nth(1).unwrap_or_else(|| "sm3".into());
    let steps: u64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let mut cfg = TrainConfig::default();
    cfg.model = "mt_small".into();
    cfg.optim.name = opt.clone();
    // paper-style per-optimizer base rates (Table 3, scaled to this task)
    cfg.optim.lr = match opt.as_str() {
        "adam" => 0.003,
        "adafactor" => 0.01,
        "sgdm" => 0.05,
        _ => 0.2,
    };
    cfg.optim.schedule = "paper".into();
    cfg.optim.warmup_steps = steps / 10;
    cfg.steps = steps;
    cfg.eval_every = (steps / 5).max(1);
    cfg.exec = ExecMode::Split;

    println!("translation: mt_small with {opt} for {steps} steps");
    let mut trainer = Trainer::new(cfg)?;
    if let Some(o) = trainer.optimizer() {
        println!("  optimizer state: {} floats", o.state_floats());
    }
    let b0 = trainer.bleu()?;
    println!("  BLEU at init: {:.2} (smoothed {:.2})", b0.bleu, b0.bleu_smooth);

    let hist = trainer.train()?;
    for e in &hist.evals {
        println!("  step {:>5}: eval loss {:.4} (ppl {:>7.2})  BLEU {:.2}",
                 e.step, e.loss, e.loss.exp(),
                 e.metric.unwrap_or(f64::NAN));
    }
    let b1 = trainer.bleu()?;
    println!("\n  final corpus BLEU: {:.2} / smoothed {:.2} \
              (bp {:.3}, precisions {:?})",
             b1.bleu, b1.bleu_smooth, b1.brevity_penalty,
             b1.precisions.map(|p| (p * 100.0).round() / 100.0));
    Ok(())
}
