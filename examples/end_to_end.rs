//! End-to-end driver: train the lm_small transformer for a few hundred
//! steps through the full three-layer stack and log the loss curve.
//!
//! This is the repository's system-level validation (see EXPERIMENTS.md
//! §End-to-end): Layer-1 Pallas SM3 kernel + Layer-2 JAX transformer,
//! AOT-lowered to an HLO artifact, executed step-by-step by the Layer-3
//! Rust coordinator on the fused path — Python never runs.
//!
//! Run: `cargo run --release --example end_to_end [-- steps]`
//! Writes out/end_to_end_loss.csv.

use anyhow::Result;
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;
use sm3::metrics::RunLogger;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut cfg = TrainConfig::default();
    cfg.model = "lm_small".into();
    cfg.optim.name = "sm3".into();
    cfg.optim.lr = 0.25;
    cfg.optim.warmup_steps = 30;
    cfg.steps = steps;
    cfg.eval_every = 50;
    cfg.exec = ExecMode::Fused;

    println!("end-to-end: lm_small ({} steps, fused SM3 path)", steps);
    let mut trainer = Trainer::new(cfg)?;
    println!("  {:.2}M params, batch {}, seq {}",
             trainer.meta.param_count as f64 / 1e6,
             trainer.meta.batch, trainer.meta.seq);

    // The split path builds its optimizer through the composable
    // OptimSpec API (DESIGN.md §11) — same model, with gradient clipping
    // and decoupled weight decay chained around SM3. The fused artifact
    // below bakes the bare SM3 kernel instead, so the spec is only
    // *described* here (the static accountant prices it without
    // allocating any state); `--exec split --clip-norm 1.0
    // --weight-decay 0.01` trains through it.
    let split_spec = sm3::optim::OptimSpec::named("sm3")?
        .clip_by_global_norm(1.0)
        .weight_decay(0.01);
    let split_floats = sm3::memory::opt_state_floats(
        split_spec.method().registry_name(),
        &trainer.meta.param_specs())?
        + sm3::memory::TRANSFORM_STATE_FLOATS;
    println!("  split-path spec: {} + clip(1.0) + decay(0.01) — \
              {:.2}M state floats",
             split_spec.method().registry_name(),
             split_floats as f64 / 1e6);

    let t0 = std::time::Instant::now();
    let hist = trainer.train()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut log = RunLogger::new(Some("out/end_to_end_loss.csv"),
                                 "step,loss,loss_ema,lr,wall_ms,comm_ms",
                                 false)?;
    for s in &hist.steps {
        log.row(&[s.step.to_string(), format!("{:.6}", s.loss),
                  format!("{:.6}", s.loss_ema), format!("{:.6e}", s.lr),
                  format!("{:.2}", s.wall_ms),
                  format!("{:.4}", s.comm_ms)])?;
    }
    log.flush()?;

    println!("\n  step    loss(ema)");
    for s in hist.steps.iter().filter(|s| s.step % 25 == 0 || s.step == 1) {
        println!("  {:>5}   {:.4}", s.step, s.loss_ema);
    }
    for e in &hist.evals {
        println!("  eval @ {:>5}: held-out loss {:.4} (ppl {:.1})",
                 e.step, e.loss, e.loss.exp());
    }
    let first = hist.steps.first().unwrap().loss;
    let last = hist.steps.last().unwrap().loss_ema;
    let tput = hist.steps.len() as f64 * trainer.meta.batch as f64
        * trainer.meta.seq as f64 / wall;
    println!("\n  loss {first:.3} -> {last:.3} in {wall:.1}s \
              ({tput:.0} tokens/s end-to-end)");
    println!("  curve written to out/end_to_end_loss.csv");
    assert!(last < first - 0.5, "training failed to make progress");
    Ok(())
}
