//! Masked-LM workload (the paper's §5.2 BERT scenario at miniature
//! scale): train the bidirectional encoder, report masked-token accuracy,
//! and demonstrate the *batch-size scaling* mechanism — the freed
//! optimizer memory funds a larger effective batch via gradient
//! accumulation, reaching target accuracy in fewer steps (Fig. 3-right).
//!
//! Run: `cargo run --release --example masked_lm -- [steps] [target_acc]`

use anyhow::Result;
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;

fn run(accum: u64, steps: u64, target: f64) -> Result<(Option<u64>, f64)> {
    let mut cfg = TrainConfig::default();
    cfg.model = "mlm_small".into();
    cfg.optim.name = "sm3".into();
    cfg.optim.lr = 0.3;
    cfg.optim.warmup_steps = 10;
    cfg.steps = steps;
    cfg.eval_every = 10;
    cfg.grad_accum = accum;
    cfg.exec = ExecMode::Split;
    let mut trainer = Trainer::new(cfg)?;
    let hist = trainer.train()?;
    let final_acc = hist.final_eval().and_then(|e| e.metric).unwrap_or(0.0);
    Ok((hist.steps_to_metric(target), final_acc))
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let target: f64 = std::env::args()
        .nth(2).and_then(|s| s.parse().ok()).unwrap_or(0.35);

    println!("masked-LM: mlm_small, SM3, target accuracy {target}");
    println!("{:>14} {:>16} {:>12}", "batch(eff.)", "steps→target", "final acc");
    for accum in [1u64, 2, 4] {
        let (steps_to, acc) = run(accum, steps, target)?;
        let reached = steps_to
            .map(|s| s.to_string())
            .unwrap_or_else(|| "not reached".into());
        println!("{:>14} {:>16} {:>11.1}%",
                 format!("{}x", accum), reached, acc * 100.0);
    }
    println!("\nlarger effective batches (funded by SM3's memory savings on \
              real hardware)\nreach the target in fewer optimizer steps — \
              the Fig. 3-right mechanism.");
    Ok(())
}
