//! Image-classification workload (the paper's §5.3 AmoebaNet scenario at
//! miniature scale): SM3 vs SGD+momentum on the synthetic image task,
//! reporting top-1/top-5 test accuracy (Fig. 4's comparison).
//!
//! Run: `cargo run --release --example image_classification -- [steps]`

use anyhow::Result;
use sm3::config::{ExecMode, TrainConfig};
use sm3::coordinator::Trainer;

fn run(opt: &str, lr: f64, steps: u64) -> Result<Vec<(u64, f64, f64)>> {
    let mut cfg = TrainConfig::default();
    cfg.model = "img_small".into();
    cfg.optim.name = opt.into();
    cfg.optim.lr = lr;
    cfg.optim.schedule = "paper".into();
    cfg.optim.warmup_steps = steps / 10;
    cfg.steps = steps;
    cfg.eval_every = (steps / 8).max(1);
    cfg.exec = ExecMode::Split;
    let mut t = Trainer::new(cfg)?;
    let hist = t.train()?;
    Ok(hist
        .evals
        .iter()
        .map(|e| (e.step, e.metric.unwrap_or(0.0), e.metric2.unwrap_or(0.0)))
        .collect())
}

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    println!("image classification: img_small, SM3 vs SGD+momentum ({steps} steps)");
    // paper Table 3: SM3 lr 0.5 / SGD staircase; scaled for this task
    let sm3 = run("sm3", 0.1, steps)?;
    let sgd = run("sgdm", 0.02, steps)?;

    println!("\n{:>6}  {:>18}  {:>18}", "step", "SM3 top1/top5", "SGD+m top1/top5");
    for (a, b) in sm3.iter().zip(&sgd) {
        println!("{:>6}  {:>8.1}% /{:>6.1}%  {:>8.1}% /{:>6.1}%",
                 a.0, a.1 * 100.0, a.2 * 100.0, b.1 * 100.0, b.2 * 100.0);
    }
    let (s_last, g_last) = (sm3.last().unwrap(), sgd.last().unwrap());
    println!("\nfinal: SM3 {:.1}%/{:.1}%  vs  SGD+m {:.1}%/{:.1}% \
              (paper: SM3 converges at least as well — Fig. 4)",
             s_last.1 * 100.0, s_last.2 * 100.0,
             g_last.1 * 100.0, g_last.2 * 100.0);
    Ok(())
}
